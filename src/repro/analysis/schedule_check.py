"""Schedule check: host-side verification of plan metadata.

Everything a :class:`repro.core.api.MatmulPlan` will execute is decided at
plan-build time — ppermute permutations, the steal3d assignment + pair
lists + move/reduce rounds, packed-wire consume maps, balance
permutations.  This pass re-derives the *contracts* those artifacts must
satisfy (independently of the planners that built them) and proves them
before the plan ever runs — the trust-a-fresh-plan-without-a-reference-
multiply primitive the elastic-replanning work needs.

Rules (stable ids):

* ``schedule.ppermute-bijection`` — every permutation the schedule hands
  to ``lax.ppermute`` is a complete bijection on the ring axis with no
  self-sends (a missing source deadlocks the neighbour exchange; a
  duplicate destination silently drops a tile).
* ``schedule.steal-exactly-once`` — decoding the steal3d pair lists
  against the LPT assignment and A's structure, every (i, k, j) work
  item's real block products are accumulated exactly once across all
  devices/segments, with consistent joins and output slots.
* ``schedule.steal-conservation`` — steal3d's moved-tile gather indices,
  reduce-round slot/row selectors and pool layout conserve blocks: every
  needed tile ships, every off-owner partial rides home, inert padding
  references guaranteed-zero pool entries, pair lists stay slot-sorted
  with full coverage.
* ``schedule.wire-contract`` — packed-wire ``pack_idx``/consume
  maps/``slot_map``/``dmap`` satisfy the ``bsr_spmm_raw(augment=False)``
  contract (rows sorted, every block-row present, real blocks exactly
  once, inert padding proven structurally zero) and the per-step maps
  match the algorithm's published tile schedule.
* ``schedule.sparse-pairs-exactly-once`` — sparse-output pair lists
  accumulate every structural block product exactly once, slot-sorted
  with full coverage, and the step->k schedule is a bijection.
* ``schedule.balance-identity`` — balance permutations on the operands
  compose to identity through the epilogue's inverse.

A decode failure on corrupted metadata is itself a detection: each rule
converts unexpected decode errors into a finding rather than raising.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from .findings import Finding

_MAX_PER_RULE = 8      # cap repeated findings per rule (keep errors readable)


def _perm_problems(perm, g: int) -> List[str]:
    perm = list(perm)
    out = []
    if len(perm) != g:
        out.append(f"has {len(perm)} pairs for a {g}-device axis")
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if sorted(srcs) != list(range(g)):
        out.append(f"sources {sorted(srcs)} are not a complete cover of "
                   f"0..{g - 1} (a missing source deadlocks the exchange; "
                   "a duplicate sends twice)")
    if sorted(dsts) != list(range(g)):
        out.append(f"destinations {sorted(dsts)} are not a complete cover "
                   f"of 0..{g - 1} (a dropped destination loses a tile)")
    if g > 1 and any(s == d for s, d in perm):
        out.append(f"contains self-sends {[p for p in perm if p[0] == p[1]]}"
                   " (a device must not be its own neighbour on a ring "
                   "of size > 1)")
    return out


_RING_SIGNS = {"ring_c": (1,), "ring_a": (1,), "ring_c_bidir": (1, -1)}


def check_perms(plan) -> List[Finding]:
    """schedule.ppermute-bijection over every perm the plan's body uses."""
    from repro.core import api as _api
    g = plan.geom.g
    perms: List[Tuple[str, tuple]] = []
    if plan.steal is not None:
        sp = plan.steal
        for what, deltas in (("a_move", sp.a_deltas), ("b_move", sp.b_deltas),
                             ("row_reduce", sp.row_deltas),
                             ("col_reduce", sp.col_deltas)):
            for delta in deltas:
                perms.append((f"steal3d {what} delta={delta}",
                              _api._steal3d_perm(g, delta)))
    for sign in _RING_SIGNS.get(plan.algorithm.name, ()):
        perms.append((f"{plan.algorithm.name} ring sign={sign:+d}",
                      _api._ring_perm(g, sign)))
    findings = []
    for label, perm in perms:
        for prob in _perm_problems(perm, g):
            findings.append(Finding(
                "schedule.ppermute-bijection",
                f"{label} permutation {tuple(perm)} {prob}",
                subject=plan.algorithm.name))
    return findings


def check_balance(plan, a_h, b_h) -> List[Finding]:
    """schedule.balance-identity: epilogue inverses undo the perms."""
    findings = []
    for h, who, attr, inv_fn in (
            (a_h, "left", "row_block_perm", "inv_row_perm"),
            (b_h, "right", "col_block_perm", "inv_col_perm")):
        perm = getattr(h, attr, None)
        if not perm:
            continue
        p = np.asarray(perm)
        n = len(p)
        if sorted(p.tolist()) != list(range(n)):
            findings.append(Finding(
                "schedule.balance-identity",
                f"{who} operand's {attr} {tuple(perm)} is not a "
                f"permutation of 0..{n - 1}; the epilogue cannot undo it",
                subject=who))
            continue
        inv = np.asarray(getattr(h, inv_fn)())
        if not (np.array_equal(p[inv], np.arange(n))
                and np.array_equal(inv[p], np.arange(n))):
            findings.append(Finding(
                "schedule.balance-identity",
                f"{who} operand's {attr} does not compose to identity "
                f"with {inv_fn}() — the epilogue would return permuted "
                "output",
                subject=who))
    return findings


# ---------------------------------------------------------------------------
# packed-wire contract
# ---------------------------------------------------------------------------
def _check_po_contract(po, sa, who: str) -> List[Finding]:
    """Per-tile PackedOperand contract against the operand structure."""
    findings = []
    g = sa.real.shape[0]
    wc, nbr = po.wire_capacity, po.tile_nbr
    for i in range(g):
        for j in range(g):
            if len(findings) >= _MAX_PER_RULE:
                return findings
            real = np.nonzero(sa.real[i, j])[0]
            nr = len(real)
            pk = po.pack_idx[i, j]
            if not np.array_equal(np.sort(pk[:nr]), real):
                findings.append(Finding(
                    "schedule.wire-contract",
                    f"{who} tile ({i},{j}): pack_idx prefix {pk[:nr]} does "
                    f"not select the tile's {nr} real stored slots "
                    f"{real} exactly once — blocks would ship "
                    "duplicated/dropped",
                    subject=f"{who}[{i},{j}]"))
                continue
            if nr < wc and sa.real[i, j][pk[nr:]].any():
                findings.append(Finding(
                    "schedule.wire-contract",
                    f"{who} tile ({i},{j}): pack_idx padding gathers a "
                    "real stored slot — the inert tail must be "
                    "structurally zero",
                    subject=f"{who}[{i},{j}]"))
            # slot_map: stored -> packed, inert slots -> guaranteed zero
            sm = po.slot_map[i, j]
            for sl in range(sm.shape[0]):
                if sa.real[i, j][sl]:
                    if pk[sm[sl]] != sl:
                        findings.append(Finding(
                            "schedule.wire-contract",
                            f"{who} tile ({i},{j}): slot_map[{sl}] = "
                            f"{sm[sl]} but pack_idx maps that packed slot "
                            f"to stored slot {pk[sm[sl]]} — remapped pair "
                            "lists would read the wrong block",
                            subject=f"{who}[{i},{j}]"))
                        break
                elif sm[sl] < nr:
                    findings.append(Finding(
                        "schedule.wire-contract",
                        f"{who} tile ({i},{j}): inert stored slot {sl} "
                        f"maps to real packed slot {sm[sl]} — padding "
                        "would alias a real block",
                        subject=f"{who}[{i},{j}]"))
                    break
            # consume lists: bsr_spmm_raw(augment=False) contract
            gx, rw, cl = po.gidx[i, j], po.rows[i, j], po.cols[i, j]
            prob = None
            if (np.diff(rw) < 0).any():
                prob = f"consume rows {rw} are not nondecreasing"
            elif set(range(nbr)) - set(rw.tolist()):
                prob = (f"consume rows miss block-rows "
                        f"{sorted(set(range(nbr)) - set(rw.tolist()))} "
                        "(first-visit zeroing skips them)")
            elif gx.min() < 0 or gx.max() >= wc:
                prob = f"gather index out of the packed range [0, {wc})"
            else:
                seen = Counter()
                for m in range(len(gx)):
                    s = int(gx[m])
                    if s < nr:
                        seen[s] += 1
                        if rw[m] != sa.rows[i, j][pk[s]] \
                                or cl[m] != sa.cols[i, j][pk[s]]:
                            prob = (f"consume entry {m} gathers packed "
                                    f"slot {s} (stored {pk[s]}) but "
                                    f"labels it ({rw[m]},{cl[m]}) instead "
                                    f"of ({sa.rows[i, j][pk[s]]},"
                                    f"{sa.cols[i, j][pk[s]]})")
                            break
                if prob is None and (set(seen) != set(range(nr))
                                     or any(v != 1 for v in seen.values())):
                    prob = (f"real packed slots consumed "
                            f"{dict(seen)} times — exactly-once violated")
            if prob:
                findings.append(Finding(
                    "schedule.wire-contract",
                    f"{who} tile ({i},{j}): {prob}",
                    subject=f"{who}[{i},{j}]"))
            # densify-by-gather map
            dm = po.dmap[i, j]
            lookup = {(int(sa.rows[i, j][sl]), int(sa.cols[i, j][sl])): sl
                      for sl in real}
            for p in range(len(dm)):
                br, bc = divmod(p, po.tile_nbc)
                s = int(dm[p])
                if (br, bc) in lookup:
                    if s >= nr or pk[s] != lookup[(br, bc)]:
                        findings.append(Finding(
                            "schedule.wire-contract",
                            f"{who} tile ({i},{j}): dmap[{p}] does not "
                            f"gather the real block at ({br},{bc}) — "
                            "densified tile would drop it",
                            subject=f"{who}[{i},{j}]"))
                        break
                elif s < nr:
                    findings.append(Finding(
                        "schedule.wire-contract",
                        f"{who} tile ({i},{j}): dmap[{p}] gathers real "
                        f"packed slot {s} into an empty dense position "
                        f"({br},{bc}) — densified tile gains a phantom "
                        "block",
                        subject=f"{who}[{i},{j}]"))
                    break
    return findings


def _wire_schedules(alg_name: str, g: int, a_po, b_po):
    """(a_tiles, a_bases, a_bwd_tiles, b_tiles, b_bases) per algorithm."""
    from repro.core import wire as _wire
    from repro.core.api import _summa_bases
    tbl = {
        "ring_c": (_wire.tiles_ring_c(g), None, None,
                   _wire.tiles_ring_c_b(g), None),
        "ring_c_bidir": (_wire.tiles_ring_c(g), None,
                         _wire.tiles_ring_c_bwd(g), None, None),
        "ring_a": (None, None, None, _wire.tiles_ring_a_b(g), None),
        "summa_ag": (_wire.tiles_summa_a(g),
                     None if a_po is None
                     else _summa_bases(g, a_po.wire_capacity),
                     None, _wire.tiles_summa_b(g),
                     None if b_po is None
                     else _summa_bases(g, b_po.wire_capacity)),
        "summa_bcast": (_wire.tiles_summa_a(g), None, None,
                        _wire.tiles_summa_b(g), None),
    }
    return tbl.get(alg_name)


def check_wire(plan, a_h, b_h) -> List[Finding]:
    """schedule.wire-contract for packed dense-output plans."""
    if plan.wire != "packed" or plan.steal is not None \
            or plan.symbolic is not None:
        return []
    findings = []
    g = plan.geom.g
    a_po = a_h.packed_operand() if "a" in plan._packs else None
    b_po = b_h.packed_operand() if "b" in plan._packs else None
    if a_po is not None:
        findings += _check_po_contract(a_po, a_h.grid_structure(), "A")
    if b_po is not None:
        findings += _check_po_contract(b_po, b_h.grid_structure(), "B")
    sched = _wire_schedules(plan.algorithm.name, g, a_po, b_po)
    if sched is None:
        return findings
    a_tiles, a_bases, a_bwd, b_tiles, b_bases = sched
    aux = {k: np.asarray(v) for k, v in plan._aux.items()}

    def expect_gather(po, arr, tiles, bases):
        out = arr[tiles[..., 0], tiles[..., 1]]
        if bases is not None:
            out = out + bases[..., None].astype(out.dtype)
        return out

    pairs = []
    if a_po is not None and a_tiles is not None:
        pairs += [("a_gidx", a_po, a_po.gidx, a_tiles, a_bases),
                  ("a_rows", a_po, a_po.rows, a_tiles, None),
                  ("a_cols", a_po, a_po.cols, a_tiles, None)]
    if a_po is not None and a_bwd is not None:
        pairs += [("a_gidx_bwd", a_po, a_po.gidx, a_bwd, None),
                  ("a_rows_bwd", a_po, a_po.rows, a_bwd, None),
                  ("a_cols_bwd", a_po, a_po.cols, a_bwd, None)]
    if b_po is not None and b_tiles is not None:
        pairs += [("b_dmap", b_po, b_po.dmap, b_tiles, b_bases)]
    for key, po, arr, tiles, bases in pairs:
        if key not in aux:
            findings.append(Finding(
                "schedule.wire-contract",
                f"packed plan is missing consume map {key!r} — the body "
                "cannot reconstruct the shipped tiles",
                subject=plan.algorithm.name))
            continue
        want = expect_gather(po, arr, tiles, bases)
        if not np.array_equal(aux[key], want):
            bad = np.argwhere(aux[key] != want)
            i, j, t = bad[0][:3]
            findings.append(Finding(
                "schedule.wire-contract",
                f"consume map {key!r} disagrees with the "
                f"{plan.algorithm.name} tile schedule (first mismatch at "
                f"device ({i},{j}) step {t}) — the receiver would "
                "reassemble the wrong tile",
                subject=plan.algorithm.name))
    return findings


# ---------------------------------------------------------------------------
# sparse-output pair lists
# ---------------------------------------------------------------------------
def check_sparse_pairs(plan, a_h, b_h) -> List[Finding]:
    """schedule.sparse-pairs-exactly-once over the committed pair lists."""
    if plan.symbolic is None:
        return []
    findings = []
    sym = plan.symbolic
    g = sym.g
    sa, sb = a_h.grid_structure(), b_h.grid_structure()
    store = sym.store_capacity
    packed = plan.wire == "packed"
    a_po = a_h.packed_operand() if packed else None
    b_po = b_h.packed_operand() if packed else None
    pairs = {k: np.asarray(v) for k, v in plan._pairs.items()}
    k_order = plan.algorithm.k_order

    def decode(po, s_struct, ti, tj, v):
        """(real, stored_slot) of an operand pair value."""
        if po is None:
            return bool(s_struct.real[ti, tj][v]), int(v)
        nr = int(po.n_real[ti, tj])
        return int(v) < nr, int(po.pack_idx[ti, tj][v])

    got: Counter = Counter()
    for i in range(g):
        for j in range(g):
            ks = [int(np.asarray(k_order(i, j, t, g))) for t in range(g)]
            if sorted(ks) != list(range(g)):
                findings.append(Finding(
                    "schedule.sparse-pairs-exactly-once",
                    f"k_order at device ({i},{j}) visits {ks} — not a "
                    "bijection over inner steps, so some k panel is "
                    "consumed twice and another dropped",
                    subject=plan.algorithm.name))
                continue
            for t, k in enumerate(ks):
                pa, pb, ps = (pairs[x][i, j, t] for x in ("pa", "pb", "ps"))
                if (np.diff(ps) < 0).any():
                    findings.append(Finding(
                        "schedule.sparse-pairs-exactly-once",
                        f"pair list at device ({i},{j}) step {t} is not "
                        "slot-sorted — first-visit zeroing would reset "
                        "accumulated slots",
                        subject=plan.algorithm.name))
                if set(range(store)) - set(ps.tolist()):
                    findings.append(Finding(
                        "schedule.sparse-pairs-exactly-once",
                        f"pair list at device ({i},{j}) step {t} misses "
                        "output slots "
                        f"{sorted(set(range(store)) - set(ps.tolist()))[:4]}"
                        " — uninitialized slots survive first-visit "
                        "zeroing",
                        subject=plan.algorithm.name))
                for p in range(pa.shape[0]):
                    ar, asl = decode(a_po, sa, i, k, pa[p])
                    br_, bsl = decode(b_po, sb, k, j, pb[p])
                    if not (ar and br_):
                        continue               # inert coverage/padding pair
                    qa = int(sa.cols[i, k][asl])
                    qb = int(sb.rows[k, j][bsl])
                    s = int(ps[p])
                    if qa != qb:
                        findings.append(Finding(
                            "schedule.sparse-pairs-exactly-once",
                            f"device ({i},{j}) k={k}: pair joins A block "
                            f"col {qa} with B block row {qb} — not a "
                            "structural product",
                            subject=plan.algorithm.name))
                        continue
                    if not sym.c_real[i, j][s] \
                            or sym.c_rows[i, j][s] != sa.rows[i, k][asl] \
                            or sym.c_cols[i, j][s] != sb.cols[k, j][bsl]:
                        findings.append(Finding(
                            "schedule.sparse-pairs-exactly-once",
                            f"device ({i},{j}) k={k}: real product targets "
                            f"slot {s} whose layout entry is "
                            f"({sym.c_rows[i, j][s]},{sym.c_cols[i, j][s]},"
                            f"real={bool(sym.c_real[i, j][s])}) — the "
                            "accumulation lands on the wrong output block",
                            subject=plan.algorithm.name))
                    got[(i, j, k, asl, bsl)] += 1
                if len(findings) >= _MAX_PER_RULE:
                    break

    want: Counter = Counter()
    for i in range(g):
        for j in range(g):
            for k in range(g):
                ra = np.nonzero(sa.real[i, k])[0]
                rb = np.nonzero(sb.real[k, j])[0]
                ca = sa.cols[i, k][ra]
                rb_rows = sb.rows[k, j][rb]
                hit = ca[:, None] == rb_rows[None, :]
                for ai, bi in zip(*np.nonzero(hit)):
                    want[(i, j, k, int(ra[ai]), int(rb[bi]))] += 1
    for key, n in list(want.items()):
        if got.get(key, 0) != n and len(findings) < _MAX_PER_RULE:
            i, j, k, asl, bsl = key
            findings.append(Finding(
                "schedule.sparse-pairs-exactly-once",
                f"structural product A[{i},{k}] slot {asl} x B[{k},{j}] "
                f"slot {bsl} is accumulated {got.get(key, 0)} time(s) "
                f"instead of exactly once on device ({i},{j})",
                subject=plan.algorithm.name))
    for key in got:
        if key not in want and len(findings) < _MAX_PER_RULE:
            i, j, k, asl, bsl = key
            findings.append(Finding(
                "schedule.sparse-pairs-exactly-once",
                f"pair list accumulates A[{i},{k}] slot {asl} x "
                f"B[{k},{j}] slot {bsl}, which is not a structural "
                "product — spurious accumulation",
                subject=plan.algorithm.name))
    return findings


# ---------------------------------------------------------------------------
# steal3d: exactly-once accumulation + conservation
# ---------------------------------------------------------------------------
def _steal_layout(sp, sa):
    """Re-derive the deterministic pool/output layout the builder
    documents (items from the assignment, sorted need lists, pool
    positions, out_idx) — the decode frame the pair lists are checked
    against."""
    g = sp.g
    n_dev = g * g
    dev = np.asarray(sp.assignment.dev)
    items = [[] for _ in range(n_dev)]
    for i in range(g):
        for k in range(g):
            for j in range(g):
                items[int(dev[i, k, j])].append((i, k, j))
    row_js, col_is, need_a, need_b = [], [], [], []
    for d in range(n_dev):
        r, c = divmod(d, g)
        rj, ci, na, nb = set(), set(), set(), set()
        for (i, k, j) in items[d]:
            if i == r and j == c:
                continue
            if i == r:
                rj.add(j)
                nb.add((k, j))
            elif j == c:
                ci.add(i)
                na.add((i, k))
        row_js.append(sorted(rj))
        col_is.append(sorted(ci))
        need_a.append(sorted(na))
        need_b.append(sorted(nb))
    a_lists = {delta: [[t for t in need_a[d]
                        if (d // g - t[0]) % g == delta]
                       for d in range(n_dev)] for delta in sp.a_deltas}
    b_lists = {delta: [[t for t in need_b[d]
                        if (d % g - t[1]) % g == delta]
                       for d in range(n_dev)] for delta in sp.b_deltas}
    packed = sp.wire == "packed"
    wc = sp.a_wire_capacity
    a_pos = [dict() for _ in range(n_dev)]
    b_pos = [dict() for _ in range(n_dev)]
    for d in range(n_dev):
        r, c = divmod(d, g)
        for k in range(g):
            a_pos[d][(r, k)] = k * wc if packed else k
            b_pos[d][(k, c)] = k
    if packed:
        base = g * wc
        for delta, cap, rcap in zip(sp.a_deltas, sp.a_move_cap,
                                    sp.a_round_cap):
            for d in range(n_dev):
                for m, t in enumerate(a_lists[delta][d]):
                    a_pos[d][t] = base + m * rcap
            base += cap * rcap
        a_zero, a_pool_tiles = base, 0
    else:
        base = g
        for delta, cap in zip(sp.a_deltas, sp.a_move_cap):
            for d in range(n_dev):
                for m, t in enumerate(a_lists[delta][d]):
                    a_pos[d][t] = base + m
            base += cap
        a_pool_tiles = base
        a_zero = base * sp.store_a if sp.a_kind == "bsr" else base
    base = g
    for delta, cap in zip(sp.b_deltas, sp.b_move_cap):
        for d in range(n_dev):
            for m, t in enumerate(b_lists[delta][d]):
                b_pos[d][t] = base + m
        base += cap
    n_row_max = max(len(v) for v in row_js)
    out_idx = []
    for d in range(n_dev):
        r, c = divmod(d, g)
        m = {(r, c): 0}
        for t, j in enumerate(row_js[d]):
            m[(r, j)] = 1 + t
        for t, i in enumerate(col_is[d]):
            m[(i, c)] = 1 + n_row_max + t
        out_idx.append(m)
    out_rows = [dict() for _ in range(n_dev)]
    if sa is not None:
        for d in range(n_dev):
            for (i, k, j) in items[d]:
                sl = np.nonzero(sa.real[i, k])[0]
                if len(sl):
                    out_rows[d].setdefault((i, j), set()).update(
                        sa.rows[i, k][sl].tolist())
    return dict(items=items, need_a=need_a, need_b=need_b,
                a_lists=a_lists, b_lists=b_lists, a_pos=a_pos, b_pos=b_pos,
                a_zero=a_zero, a_pool_tiles=a_pool_tiles, out_idx=out_idx,
                out_rows=out_rows, dev=dev)


def _decode_steal_pairs(sp, sa, lay, aux, seg, findings):
    """Decode one pair-list segment into a multiset of executed products.

    ``seg`` is ("", full-pool) for bulk plans, ("0", panel-pool) /
    ("1", full-pool) for overlap plans.  Returns Counter of
    (i, k, j, stored_slot) — stored_slot is 0 for dense A.
    """
    suffix, panel_only = seg
    g = sp.g
    packed = sp.wire == "packed"
    sparse_a = sp.a_kind == "bsr"
    wc = sp.a_wire_capacity
    nbr = sa.real.shape[2] and int(sa.rows.shape[2]) or 1  # unused default
    nbr = int(np.max(sa.rows) + 1) if sparse_a else 1
    if sparse_a:
        nbr = sa.tile_nbr
    pa_arr = aux[f"pa{suffix}"]
    pb_arr = aux[f"pb{suffix}"]
    ps_arr = aux[f"ps{suffix}"]
    if panel_only:
        a_zero = g * wc if packed else (
            g * sp.store_a if sparse_a else g)
    else:
        a_zero = lay["a_zero"]
    # flat packed intervals: (base, stride, tile) in base order
    intervals = []
    if packed:
        for k in range(g):
            intervals.append((k * wc, wc, None, k))   # panel: tile (r, k)
        if not panel_only:
            base = g * wc
            for delta, cap, rcap in zip(sp.a_deltas, sp.a_move_cap,
                                        sp.a_round_cap):
                intervals.append((base, rcap, delta, None))
                base += cap * rcap
    got: Counter = Counter()
    inv_out = [{o: key for key, o in lay["out_idx"][d].items()}
               for d in range(g * g)]
    inv_b = [{pos: t for t, pos in lay["b_pos"][d].items()}
             for d in range(g * g)]
    inv_a = [{pos: t for t, pos in lay["a_pos"][d].items()}
             for d in range(g * g)]
    for d in range(g * g):
        r, c = divmod(d, g)
        ps_dev = ps_arr[r, c]
        if sparse_a and (np.diff(ps_dev) < 0).any():
            findings.append(Finding(
                "schedule.steal-conservation",
                f"device ({r},{c}) pair list (segment {suffix or 'bulk'}) "
                "is not slot-sorted — first-visit zeroing would reset "
                "accumulated slots",
                subject="steal3d"))
        if sparse_a and set(range(sp.n_slots)) - set(ps_dev.tolist()):
            findings.append(Finding(
                "schedule.steal-conservation",
                f"device ({r},{c}) pair list (segment {suffix or 'bulk'}) "
                "misses output slots — uninitialized accumulator slots "
                "survive first-visit zeroing",
                subject="steal3d"))
        for p in range(pa_arr.shape[2]):
            va = int(pa_arr[r, c, p])
            if va == a_zero:
                continue                       # inert coverage/padding
            # --- decode the A side to (tile, stored slot) ---
            if packed:
                tile = off = None
                for base, stride, delta, k in intervals:
                    span = stride * (1 if k is not None else
                                     len(lay["a_lists"][delta][d]) or 1)
                    if k is not None:
                        lo, hi = base, base + stride
                        if lo <= va < hi:
                            tile, off = (r, k), va - lo
                            break
                    else:
                        lst = lay["a_lists"][delta][d]
                        lo, hi = base, base + stride * len(lst)
                        if lo <= va < hi and lst:
                            m, off = divmod(va - lo, stride)
                            tile = lst[m]
                            break
                if tile is None:
                    findings.append(Finding(
                        "schedule.steal-exactly-once",
                        f"device ({r},{c}) pair {p}: packed pool index "
                        f"{va} addresses no gathered or moved tile — "
                        "reads junk as real work",
                        subject="steal3d"))
                    continue
                i, k_a = tile
                nz = np.nonzero(sa.real[i, k_a])[0]
                if off >= len(nz):
                    continue                   # packed zero tail: inert
                stored = int(nz[off])
            elif sparse_a:
                pos, stored = divmod(va, sp.store_a)
                if pos not in inv_a[d] or (panel_only and pos >= g):
                    findings.append(Finding(
                        "schedule.steal-exactly-once",
                        f"device ({r},{c}) pair {p}: pool position {pos} "
                        "addresses no gathered or moved tile — reads "
                        "junk as real work",
                        subject="steal3d"))
                    continue
                i, k_a = inv_a[d][pos]
                if not sa.real[i, k_a][stored]:
                    continue                   # structurally zero: inert
            else:
                if va not in inv_a[d] or (panel_only and va >= g):
                    findings.append(Finding(
                        "schedule.steal-exactly-once",
                        f"device ({r},{c}) pair {p}: pool position {va} "
                        "addresses no gathered or moved tile",
                        subject="steal3d"))
                    continue
                i, k_a = inv_a[d][va]
                stored = 0
            # --- decode output slot and B chunk; check the join ---
            vs = int(ps_arr[r, c, p])
            vb = int(pb_arr[r, c, p])
            o, rhat = divmod(vs, nbr) if sparse_a else (vs, 0)
            if o not in inv_out[d]:
                findings.append(Finding(
                    "schedule.steal-exactly-once",
                    f"device ({r},{c}) pair {p}: output slot {o} maps to "
                    "no (i, j) accumulator on this device",
                    subject="steal3d"))
                continue
            oi, oj = inv_out[d][o]
            bpos, q = divmod(vb, sp.b_chunks) if sparse_a else (vb, 0)
            if bpos not in inv_b[d]:
                findings.append(Finding(
                    "schedule.steal-exactly-once",
                    f"device ({r},{c}) pair {p}: B pool position {bpos} "
                    "addresses no gathered or moved B tile",
                    subject="steal3d"))
                continue
            bk, bj = inv_b[d][bpos]
            ok = (oi == i and bj == oj and bk == k_a)
            if sparse_a:
                ok = ok and q == int(sa.cols[i, k_a][stored]) \
                    and rhat == int(sa.rows[i, k_a][stored])
            if not ok:
                findings.append(Finding(
                    "schedule.steal-exactly-once",
                    f"device ({r},{c}) pair {p}: inconsistent join — A "
                    f"block ({i},{k_a}) slot {stored} paired with B tile "
                    f"({bk},{bj}) chunk {q} into output ({oi},{oj}) row "
                    f"{rhat}",
                    subject="steal3d"))
                continue
            item = (i, k_a, oj)
            if panel_only is not None and suffix == "0" \
                    and not (i == r and oj == c):
                findings.append(Finding(
                    "schedule.steal-conservation",
                    f"device ({r},{c}): stolen item {item} scheduled in "
                    "the own-items segment — it would execute before its "
                    "moved tile arrives",
                    subject="steal3d"))
            if suffix == "1" and (i == r and oj == c):
                findings.append(Finding(
                    "schedule.steal-conservation",
                    f"device ({r},{c}): own item {item} scheduled in the "
                    "stolen segment — serialized behind the move rounds "
                    "for no reason",
                    subject="steal3d"))
            if int(lay["dev"][i, k_a, oj]) != d:
                findings.append(Finding(
                    "schedule.steal-exactly-once",
                    f"item {item} executes on device ({r},{c}) but the "
                    f"assignment placed it on device "
                    f"{divmod(int(lay['dev'][i, k_a, oj]), g)}",
                    subject="steal3d"))
            got[item + (stored,)] += 1
            if len(findings) >= _MAX_PER_RULE:
                return got
    return got


def check_steal(plan, a_h) -> List[Finding]:
    """steal3d exactly-once + conservation over the plan's aux arrays."""
    if plan.steal is None:
        return []
    sp = plan.steal
    g = sp.g
    n_dev = g * g
    sparse_a = sp.a_kind == "bsr"
    sa = a_h.grid_structure() if sparse_a else None
    findings: List[Finding] = []
    lay = _steal_layout(sp, sa)
    aux = sp.aux

    # -- exactly-once: decode every segment, compare against the assignment
    segs = [("0", True), ("1", False)] if sp.overlap else [("", False)]
    got: Counter = Counter()
    for seg in segs:
        got += _decode_steal_pairs(sp, sa, lay, aux, seg, findings)
    want: Counter = Counter()
    for i in range(g):
        for k in range(g):
            for j in range(g):
                if sparse_a:
                    for sl in np.nonzero(sa.real[i, k])[0]:
                        want[(i, k, j, int(sl))] += 1
                else:
                    want[(i, k, j, 0)] += 1
    for key, n in want.items():
        if got.get(key, 0) != n and len(findings) < _MAX_PER_RULE:
            i, k, j, sl = key
            findings.append(Finding(
                "schedule.steal-exactly-once",
                f"work item ({i},{k},{j}) stored slot {sl} is accumulated "
                f"{got.get(key, 0)} time(s) across all devices instead of "
                "exactly once — the result would be "
                f"{'missing' if got.get(key, 0) == 0 else 'double-counted'}"
                " this block product",
                subject="steal3d"))
    for key in got:
        if key not in want and len(findings) < _MAX_PER_RULE:
            findings.append(Finding(
                "schedule.steal-exactly-once",
                f"pair lists accumulate {key[:3]} stored slot {key[3]}, "
                "which is not real structural work",
                subject="steal3d"))

    # -- conservation: move rounds ship exactly the needed tiles ----------
    n_real_tile = sa.real.sum(axis=2) if sparse_a else None
    for d in range(n_dev):
        for t in lay["need_a"][d]:
            delta = (d // g - t[0]) % g
            if delta not in sp.a_deltas and not (
                    sp.wire == "packed" and int(n_real_tile[t]) == 0):
                findings.append(Finding(
                    "schedule.steal-conservation",
                    f"device {divmod(d, g)} needs moved A tile {t} at hop "
                    f"{delta} but no such move round exists — the item "
                    "would compute on a stale pool slot",
                    subject="steal3d"))
        for t in lay["need_b"][d]:
            delta = (d % g - t[1]) % g
            if delta not in sp.b_deltas:
                findings.append(Finding(
                    "schedule.steal-conservation",
                    f"device {divmod(d, g)} needs moved B tile {t} at hop "
                    f"{delta} but no such move round exists",
                    subject="steal3d"))
    for delta in sp.a_deltas:
        arr = aux[f"amk{delta}"]
        for d in range(n_dev):
            s = ((d // g - delta) % g, d % g)
            for m, t in enumerate(lay["a_lists"][delta][d]):
                if int(arr[s[0], s[1], m]) != t[1]:
                    findings.append(Finding(
                        "schedule.steal-conservation",
                        f"A move round delta={delta}: source {s} packs "
                        f"panel position {int(arr[s[0], s[1], m])} into "
                        f"lane {m} but receiver {divmod(d, g)} expects "
                        f"tile {t} (panel position {t[1]}) — the thief "
                        "computes with the wrong tile",
                        subject="steal3d"))
                    break
    for delta in sp.b_deltas:
        arr = aux[f"bmk{delta}"]
        for d in range(n_dev):
            s = (d // g, (d % g - delta) % g)
            for m, t in enumerate(lay["b_lists"][delta][d]):
                if int(arr[s[0], s[1], m]) != t[0]:
                    findings.append(Finding(
                        "schedule.steal-conservation",
                        f"B move round delta={delta}: source {s} packs "
                        f"panel position {int(arr[s[0], s[1], m])} into "
                        f"lane {m} but receiver {divmod(d, g)} expects "
                        f"tile {t} (panel position {t[0]})",
                        subject="steal3d"))
                    break

    # -- conservation: every off-owner partial rides home -----------------
    dummy_idx = sp.n_out - 1
    packed = sp.wire == "packed"
    for d in range(n_dev):
        r, c = divmod(d, g)
        for (i, j), o in lay["out_idx"][d].items():
            if o == 0:
                continue
            if i == r:
                delta, deltas, what = (j - c) % g, sp.row_deltas, "row"
            else:
                delta, deltas, what = (i - r) % g, sp.col_deltas, "col"
            if delta not in deltas and not (
                    packed and not lay["out_rows"][d].get((i, j))):
                findings.append(Finding(
                    "schedule.steal-conservation",
                    f"device ({r},{c}) computes a partial for output tile "
                    f"({i},{j}) but no {what} reduce round at hop {delta} "
                    "exists — the partial never rides home",
                    subject="steal3d"))
    for deltas, key_of, prefix in (
            (sp.row_deltas, lambda r, c, delta: (r, (c + delta) % g), "r"),
            (sp.col_deltas, lambda r, c, delta: ((r + delta) % g, c), "c")):
        for delta in deltas:
            sel = aux[f"{prefix}send{delta}"]
            for d in range(n_dev):
                r, c = divmod(d, g)
                want_o = lay["out_idx"][d].get(key_of(r, c, delta),
                                               dummy_idx)
                if int(sel[r, c]) != want_o:
                    findings.append(Finding(
                        "schedule.steal-conservation",
                        f"{prefix}send{delta}[{r},{c}] selects output "
                        f"slot {int(sel[r, c])} but device ({r},{c})'s "
                        f"partial for that round lives in slot {want_o} — "
                        "the wrong partial (or junk) rides home",
                        subject="steal3d"))
    if packed:
        nbr = sa.tile_nbr
        for deltas, out_of, src_of, prefix in (
                (sp.row_deltas,
                 lambda d, delta: (d // g, (d % g + delta) % g),
                 lambda d, delta: (d // g) * g + (d % g - delta) % g, "r"),
                (sp.col_deltas,
                 lambda d, delta: ((d // g + delta) % g, d % g),
                 lambda d, delta: ((d // g - delta) % g) * g + d % g, "c")):
            for delta in deltas:
                row = aux[f"{prefix}row{delta}"]
                tgt = aux[f"{prefix}tgt{delta}"]
                rows_of = [sorted(lay["out_rows"][d].get(
                    out_of(d, delta), ())) for d in range(n_dev)]
                for d in range(n_dev):
                    r, c = divmod(d, g)
                    mine = rows_of[d]
                    src = rows_of[src_of(d, delta)]
                    ok = list(row[r, c, :len(mine)]) == mine \
                        and list(tgt[r, c, :len(src)]) == src \
                        and (tgt[r, c, len(src):] == nbr).all()
                    if not ok:
                        findings.append(Finding(
                            "schedule.steal-conservation",
                            f"packed reduce round {prefix}{delta} at "
                            f"device ({r},{c}): shipped rows "
                            f"{list(row[r, c])} / targets "
                            f"{list(tgt[r, c])} disagree with the "
                            f"partial's touched rows {mine} (receiver "
                            f"expects {src}; padding must land on the "
                            f"dummy row {nbr})",
                            subject="steal3d"))
                        break
    return findings


def check_survivor_coverage(assignment, g: int,
                            survivors=None) -> List[Finding]:
    """``schedule.survivor-coverage``: a rebuilt assignment matches the
    surviving mesh.

    The elastic-recovery gate (``repro.runtime.replan``): after device
    loss, the steal3d :class:`~repro.core.schedule.Assignment3D` is
    rebuilt for a shrunken ``g x g`` grid.  This rule proves the rebuilt
    assignment covers *exactly* that grid's work: the work grid has the
    new shape, every (i, k, j) item is assigned (no ``-1`` holes), every
    referenced device id is a live position of the new mesh (``[0,
    g^2)``), and — when the surviving device collection is given — the
    new grid actually fits on it.  Locality/makespan invariants stay with
    ``validate_assignment``; this is purely the coverage contract.
    """
    rule = "schedule.survivor-coverage"
    findings: List[Finding] = []
    dev = np.asarray(assignment.dev if hasattr(assignment, "dev")
                     else assignment)
    if dev.shape != (g, g, g):
        return [Finding(rule,
                        f"assignment work grid has shape {dev.shape}, "
                        f"expected {(g, g, g)} for the surviving "
                        f"{g}x{g} mesh", subject="steal3d")]
    if not np.issubdtype(dev.dtype, np.integer):
        return [Finding(rule,
                        f"assignment device ids must be integers, got "
                        f"dtype {dev.dtype}", subject="steal3d")]
    if survivors is not None:
        n_surv = survivors if isinstance(survivors, int) \
            else len(tuple(survivors))
        if g * g > n_surv:
            findings.append(Finding(
                rule,
                f"a {g}x{g} grid needs {g * g} devices but only "
                f"{n_surv} survive", subject="steal3d"))
    unassigned = int((dev < 0).sum())
    if unassigned:
        holes = np.argwhere(dev < 0)[:3].tolist()
        findings.append(Finding(
            rule,
            f"{unassigned} work item(s) unassigned (dev < 0), e.g. "
            f"{holes} — recovery would silently drop their block "
            "products", subject="steal3d"))
    dead = int((dev >= g * g).sum())
    if dead:
        ids = sorted(set(int(d) for d in dev[dev >= g * g].ravel()))[:4]
        findings.append(Finding(
            rule,
            f"{dead} work item(s) assigned to device ids {ids} outside "
            f"the surviving mesh's [0, {g * g}) — those positions no "
            "longer exist", subject="steal3d"))
    return findings


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
RULES = (
    ("schedule.ppermute-bijection",
     "every ppermute permutation is a complete, self-send-free bijection"),
    ("schedule.steal-exactly-once",
     "steal3d pair lists accumulate each (i,k,j) block product exactly "
     "once across devices"),
    ("schedule.steal-conservation",
     "steal3d move/reduce rounds conserve tiles and partials; pair lists "
     "stay sorted with full slot coverage"),
    ("schedule.wire-contract",
     "packed-wire pack_idx/consume maps/slot_map/dmap satisfy the "
     "bsr_spmm_raw(augment=False) contract with inert padding proven "
     "inert"),
    ("schedule.sparse-pairs-exactly-once",
     "sparse-output pair lists accumulate each structural product "
     "exactly once, slot-sorted with full coverage"),
    ("schedule.balance-identity",
     "balance permutations compose to identity through the epilogue"),
    ("schedule.survivor-coverage",
     "a rebuilt steal3d assignment covers exactly the surviving mesh's "
     "work items: every (i,k,j) assigned, only surviving devices "
     "referenced, grid fits the survivor count"),
)


def _guard(rule: str, fn, *args) -> List[Finding]:
    try:
        return fn(*args)
    except Exception as e:                     # noqa: BLE001
        # a decode crash on corrupt metadata is a detection, not a pass
        return [Finding(
            rule,
            f"checker could not decode the plan's metadata "
            f"({type(e).__name__}: {e}) — the arrays do not satisfy the "
            "layout contract's shapes/ranges",
        )]


def check_plan(plan, a=None, b=None) -> List[Finding]:
    """Run every schedule rule that applies to ``plan``.

    ``a`` / ``b`` are the plan's operands (handles preferred); structure-
    dependent rules are skipped when they are absent.
    """
    from repro.core import api as _api
    findings = _guard("schedule.ppermute-bijection", check_perms, plan)
    if a is None or b is None:
        return findings
    a_h, b_h = _api._coerce_pair(a, b, g=plan.geom.g,
                                 allow_pad=plan._allow_pad)
    findings += _guard("schedule.balance-identity", check_balance,
                       plan, a_h, b_h)
    if plan.steal is not None:
        findings += _guard("schedule.steal-exactly-once", check_steal,
                           plan, a_h)
    if plan.symbolic is not None:
        findings += _guard("schedule.sparse-pairs-exactly-once",
                           check_sparse_pairs, plan, a_h, b_h)
    findings += _guard("schedule.wire-contract", check_wire, plan, a_h, b_h)
    return findings
