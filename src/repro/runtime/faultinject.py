"""Deterministic fault injection for the elastic replanning runtime.

Three failure modes, all seeded so tests and benchmarks replay exactly:

* :class:`StragglerInjector` — per-device step-time inflation.  A real
  straggler shows up as measured step times far above the cost model's
  prediction for that device's series; :func:`record_straggler_drift`
  writes exactly that signal into the live ``repro.obs`` drift series
  (measured = factor x predicted, from the plan's own cost model), which
  is what :class:`repro.runtime.replan.ElasticReplanner` watches.
* :class:`TransientFailure` — wraps a callable and raises on the Nth
  call, then recovers: the signal :class:`repro.runtime.fault.RestartableLoop`
  is built to absorb.
* :class:`DeviceLoss` — a seeded choice of lost devices out of a mesh,
  yielding the surviving-device set that drives grid shrink
  (``elastic.choose_grid_shape`` -> ``replan.recover_from_loss``).

Nothing here touches wall clocks: injection is synthetic and replayable,
so recovery tests gate on plan validation and numerics, not timing.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, Type

import numpy as np

__all__ = [
    "StragglerInjector",
    "TransientFailure",
    "DeviceLoss",
    "record_straggler_drift",
]


class StragglerInjector:
    """Per-device step-time inflation, deterministic in (seed, step, device).

    ``step_time(step, device, base_s)`` returns ``base_s`` untouched for
    healthy devices and ``base_s * factor * (1 + jitter * u)`` for the
    straggling device once ``step >= start_step``, with ``u`` drawn
    reproducibly from ``(seed, step, device)``.
    """

    def __init__(self, device: int, factor: float = 8.0, *, seed: int = 0,
                 jitter: float = 0.0, start_step: int = 0):
        if factor < 1.0:
            raise ValueError(f"straggler factor must be >= 1, got {factor}")
        self.device = device
        self.factor = factor
        self.seed = seed
        self.jitter = jitter
        self.start_step = start_step

    def _u(self, step: int, device: int) -> float:
        rng = np.random.default_rng((self.seed, step, device))
        return float(rng.uniform())

    def active(self, step: int, device: int) -> bool:
        return device == self.device and step >= self.start_step

    def step_time(self, step: int, device: int, base_s: float) -> float:
        if not self.active(step, device):
            return base_s
        return base_s * self.factor * (1.0 + self.jitter * self._u(step,
                                                                   device))


class TransientFailure:
    """Raise on the Nth call of the wrapped function, succeed otherwise.

    ``fail_on`` is 1-based; a list/tuple fails on each listed call.  Use
    as a wrapper factory::

        flaky = TransientFailure(fail_on=3)(plan)
        loop.run(lambda step: flaky(a, b))   # 3rd multiply raises once
    """

    def __init__(self, fail_on=1, exc_type: Type[Exception] = RuntimeError,
                 message: str = "injected transient failure"):
        self.fail_on = (set(fail_on) if isinstance(fail_on, (list, tuple, set))
                        else {int(fail_on)})
        self.exc_type = exc_type
        self.message = message
        self.calls = 0
        self.failures = 0

    def __call__(self, fn: Callable) -> Callable:
        def wrapped(*args, **kwargs):
            self.calls += 1
            if self.calls in self.fail_on:
                self.failures += 1
                raise self.exc_type(f"{self.message} (call {self.calls})")
            return fn(*args, **kwargs)

        return wrapped


class DeviceLoss:
    """Seeded simulated loss of ``n_lost`` devices out of ``n_devices``.

    ``survivors()`` is a sorted tuple of surviving device ids — the input
    to ``elastic.choose_grid_shape`` / ``replan.recover_from_loss``.
    """

    def __init__(self, n_devices: int, n_lost: int, *, seed: int = 0):
        if not 0 <= n_lost < n_devices:
            raise ValueError(
                f"need 0 <= n_lost < n_devices, got {n_lost}/{n_devices}")
        self.n_devices = n_devices
        self.n_lost = n_lost
        rng = np.random.default_rng((seed, n_devices, n_lost))
        lost = rng.choice(n_devices, size=n_lost, replace=False)
        self._lost = tuple(sorted(int(d) for d in lost))

    def lost(self) -> Tuple[int, ...]:
        return self._lost

    def survivors(self) -> Tuple[int, ...]:
        dead = set(self._lost)
        return tuple(d for d in range(self.n_devices) if d not in dead)


def record_straggler_drift(plan, *, factor: float, n: int = 4,
                           machine=None, jitter: float = 0.0,
                           seed: int = 0) -> float:
    """Write ``n`` straggler-inflated drift records for ``plan`` into the
    live obs series, without running anything.

    The measured side is ``factor x`` the plan's own cost-model
    prediction under ``machine`` (default: the current drift baseline,
    ``TPU_V5E``) — exactly the series a device running ``factor`` slow
    leaves behind, so ``obs.drift_report()`` ratios trip at ``factor``
    and ``fit_machine.fit_from_registry`` attributes the surplus to the
    network.  Returns the mean injected measured seconds.
    """
    from repro import obs
    from repro.core import roofline

    machine = machine or roofline.TPU_V5E
    inj = StragglerInjector(device=0, factor=factor, seed=seed,
                            jitter=jitter)
    predicted = plan.predicted_cost(machine)
    cm = plan.cost_model()
    total = 0.0
    for step in range(n):
        measured = inj.step_time(step, 0, predicted)
        obs.record_drift(
            plan.algorithm.name, plan.wire, plan.overlap,
            predicted_s=predicted, measured_s=measured, cm=cm,
            kind=plan.kind, machine=machine.name, injected=True)
        total += measured
    return total / max(n, 1)
