"""XLA platform / flag configuration — the repo's ONLY XLA_FLAGS writer.

XLA reads ``XLA_FLAGS`` exactly once, when the first backend initializes;
any write after that is silently dead.  Every entry point that needs
flags (fake host device counts for the multi-device suites, the
async-collective + latency-hiding-scheduler set that makes the
double-buffered schedule bodies actually overlap on GPU) therefore
routes through this module, which

* merges new flags into ``os.environ["XLA_FLAGS"]`` without clobbering
  caller-provided ones,
* is a guarded **no-op once jax is initialized** (returns ``False`` and
  warns instead of planting flags that can never take effect), and
* is the single allowed ``XLA_FLAGS`` write site, enforced by
  ``tools/check_api.py`` (the ``set_platform`` idiom from SNIPPETS.md).

Overlap flags: the engine's split-step bodies issue step i+1's
``ppermute`` before step i's accumulate, so the *program* has the slack;
these flags let XLA's GPU runtime actually use it (async collectives on
their own stream, latency-hiding scheduler to sink the ``-done`` past
independent compute).  On TPU and CPU backends they are inert but
harmless.
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import Iterable, Optional

__all__ = [
    "OVERLAP_XLA_FLAGS", "jax_initialized", "host_device_count_flag",
    "set_platform", "set_host_device_count", "subprocess_env",
]

# Overlap set (jax GPU performance tips + the set_platform idiom): the
# latency-hiding scheduler separates collective starts from their waits
# across independent compute, collectives get a dedicated high-priority
# stream, and back-to-back ring steps pipeline.  Async collectives
# themselves are default-on in current XLA — the old
# ``--xla_gpu_enable_async_collectives`` knob no longer exists (XLA
# aborts on unknown flags, so it must NOT be planted).
OVERLAP_XLA_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_enable_pipelined_collectives=true",
)


def jax_initialized() -> bool:
    """True once any jax backend exists (flags can no longer take effect)."""
    mod = sys.modules.get("jax")
    if mod is None:
        return False
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:                       # noqa: BLE001 (version drift)
        # can't introspect this jax version; assume live (be conservative:
        # callers then know their flags may be dead)
        return True


def host_device_count_flag(n: int) -> str:
    """The fake-device flag string (for building *subprocess* envs)."""
    return f"--xla_force_host_platform_device_count={int(n)}"


def _merge_flags(flags: Iterable[str], env: Optional[dict] = None) -> str:
    """Append flags to the env's XLA_FLAGS, dropping exact duplicates and
    replacing older settings of the same ``--flag=`` stem."""
    env = os.environ if env is None else env
    current = env.get("XLA_FLAGS", "").split()
    stems = {f.split("=", 1)[0] for f in flags}
    kept = [f for f in current if f.split("=", 1)[0] not in stems]
    env["XLA_FLAGS"] = " ".join(kept + list(flags)).strip()
    return env["XLA_FLAGS"]


def set_platform(platform: Optional[str] = None, *,
                 host_device_count: Optional[int] = None,
                 overlap: bool = True) -> bool:
    """Configure the XLA platform before jax initializes.

    ``platform`` ("cpu" | "gpu" | "tpu") sets ``jax_platform_name``;
    ``host_device_count`` plants the fake-CPU-device flag (the
    multi-device test/bench harness); ``overlap=True`` (default) adds
    :data:`OVERLAP_XLA_FLAGS`.  Returns ``True`` if the flags were
    planted while they can still take effect, ``False`` (with a warning,
    and without touching the environment) once jax is already
    initialized — the guard that makes wiring this into
    ``launch/mesh.py`` safe mid-process.
    """
    if jax_initialized():
        warnings.warn(
            "repro.runtime.platform.set_platform: jax is already "
            "initialized; XLA flags would be ignored (no-op)",
            RuntimeWarning, stacklevel=2)
        return False
    flags = []
    if host_device_count is not None:
        flags.append(host_device_count_flag(host_device_count))
    if overlap:
        flags.extend(OVERLAP_XLA_FLAGS)
    if flags:
        _merge_flags(flags)
    if platform is not None:
        import jax
        jax.config.update("jax_platform_name", platform)
    return True


def set_host_device_count(n: int, *, overlap: bool = False) -> bool:
    """Fake-device entry point for benches/selftests (pre-jax-init only)."""
    return set_platform(host_device_count=n, overlap=overlap)


def subprocess_env(host_device_count: Optional[int] = None, *,
                   overlap: bool = False,
                   base: Optional[dict] = None) -> dict:
    """A child-process environment with the requested XLA flags merged in.

    Unlike :func:`set_platform` this never touches the current process
    (the child's jax is by definition uninitialized), so it needs no
    init guard — it is how ``benchmarks/run.py`` and the distributed
    test suite launch their fixed-device-count workers.
    """
    env = dict(os.environ if base is None else base)
    flags = []
    if host_device_count is not None:
        flags.append(host_device_count_flag(host_device_count))
    if overlap:
        flags.extend(OVERLAP_XLA_FLAGS)
    if flags:
        _merge_flags(flags, env)
    return env
