"""Fault tolerance runtime: restartable training loop, straggler detection,
preemption handling.

Designed for the 1000+-node regime:

* every step is resumable — data batches are a pure function of (seed, step)
  and checkpoints commit atomically, so `RestartableLoop` can recover from
  any exception by restoring the latest checkpoint and re-entering the loop;
* `StragglerDetector` keeps an EWMA of step times and flags outliers (on a
  real cluster the flagged host is reported to the job scheduler for
  drain/replace; here the hook records and, optionally, raises for tests);
* `PreemptionSignal` converts SIGTERM (maintenance events) into a clean
  checkpoint-and-exit between steps.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Dict, List, Optional

__all__ = ["StragglerDetector", "PreemptionSignal", "RestartableLoop"]


class StragglerDetector:
    """EWMA step-time outlier detection (z-score on the smoothed residual)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 4.0,
                 warmup: int = 5):
        self.alpha, self.threshold, self.warmup = alpha, threshold, warmup
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.count = 0
        self.events: List[Dict] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler event."""
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return False
        resid = dt - self.mean
        slow = (self.count > self.warmup and self.var > 0 and
                resid > self.threshold * (self.var ** 0.5))
        # update stats only with non-outliers so one hang doesn't poison them
        if not slow:
            self.mean += self.alpha * resid
            self.var = (1 - self.alpha) * (self.var + self.alpha * resid ** 2)
        if slow:
            self.events.append({"step": step, "dt": dt, "mean": self.mean})
        return slow


class PreemptionSignal:
    """SIGTERM -> graceful stop flag checked between steps.

    Chains the previously installed SIGTERM handler rather than clobbering
    it, and restores it on `uninstall()` (also the context-manager exit), so
    two coexisting instances — e.g. the training loop's and the serving
    engine's — both see the signal and tear down cleanly.
    """

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = None
        self._installed = False
        if install:
            self.install()

    def install(self) -> bool:
        """Install the handler; returns False outside the main thread."""
        if self._installed:
            return True
        try:
            self._prev = signal.signal(signal.SIGTERM, self._handler)
        except ValueError:
            return False  # non-main thread (tests)
        self._installed = True
        return True

    def uninstall(self) -> None:
        """Restore whatever SIGTERM handler was active before `install()`."""
        if not self._installed:
            return
        prev = signal.SIG_DFL if self._prev is None else self._prev
        try:
            signal.signal(signal.SIGTERM, prev)
        except ValueError:
            pass
        self._installed = False
        self._prev = None

    def __enter__(self) -> "PreemptionSignal":
        self.install()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.uninstall()

    def _handler(self, signum, frame):
        self.requested = True
        if callable(self._prev):
            self._prev(signum, frame)


class RestartableLoop:
    """Run `body(step) -> None` for steps [start, total); on exception,
    call `recover() -> restart_step` and continue.  Bounded retries.

    `max_restarts` bounds *consecutive* failures: a successful step resets
    the counter, so transient faults spread across a long job don't
    accumulate into a spurious kill.  `total_restarts` keeps the lifetime
    count for reporting.
    """

    def __init__(self, total_steps: int, recover: Callable[[], int],
                 max_restarts: int = 3,
                 on_restart: Optional[Callable[[int, Exception], None]] = None):
        self.total = total_steps
        self.recover = recover
        self.max_restarts = max_restarts
        self.on_restart = on_restart
        self.restarts = 0        # consecutive failures since last progress
        self.total_restarts = 0  # lifetime failure count

    def run(self, body: Callable[[int], None], start_step: int = 0):
        step = start_step
        while step < self.total:
            try:
                body(step)
                step += 1
                self.restarts = 0
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 — any node failure
                self.restarts += 1
                self.total_restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if self.on_restart:
                    self.on_restart(step, e)
                step = self.recover()
        return step
