"""Elastic replanning: drift-triggered re-selection + degraded-mesh recovery.

The engine's plans are compiled against three assumptions — a machine
model (``Machine``), an operand structure, and a healthy g x g mesh.
This module is the control loop that repairs each of them from *live*
signals instead of restarting the job:

* **Drift** — every traced multiply leaves a predicted-vs-measured pair
  in ``obs.drift_records()`` per (algorithm, wire, overlap) series.
  :meth:`ElasticReplanner.should_replan` watches the per-series geomean
  ratio (``obs.drift_report()``) and :class:`~repro.runtime.fault.
  StragglerDetector` events; past the configured thresholds,
  :meth:`~ElasticReplanner.refit` re-fits ``(net_bw, hop_latency)`` from
  the recorded series (``tools/fit_machine.fit_from_registry``), points
  the drift baseline at the fitted machine, and evicts exactly the
  tripped algorithms' cached plans (``api.invalidate_plans`` keyed
  invalidation — everything else stays hot).
  :meth:`~ElasticReplanner.replan` then re-runs ``auto_select`` under
  the fitted machine, so a schedule that only won on nominal constants
  loses the re-selection.

* **Device loss** — :meth:`~ElasticReplanner.recover_from_loss` takes
  the surviving device set, picks the new grid
  (``elastic.choose_grid_shape``), re-tiles the live handles onto it
  device-side (``api.reshard`` — no host round-trip of block data),
  rebuilds the steal3d :class:`~repro.core.schedule.Assignment3D` for
  the survivors with ``assign_3d_lpt`` over the resharded operand's
  actual item costs, proves it covers exactly the surviving mesh's work
  (``analysis.check_survivor_coverage``) and injects it through
  ``plan_matmul(assignment=..., validate="fast")`` — recovery gates on
  the static verifier, not numerics.

Every action surfaces through ``repro.obs`` as ``replan.*`` counters
and ``replan.*`` spans, so serving dashboards see trips, refits,
evictions, recoveries and budget overruns.
"""
from __future__ import annotations

import dataclasses
import importlib.util
import math
import pathlib
import time
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["ReplanConfig", "ReplanResult", "RecoveryResult",
           "ElasticReplanner"]


def _load_fit_machine():
    """Import tools/fit_machine.py (tools/ is not a package)."""
    path = (pathlib.Path(__file__).resolve().parents[3]
            / "tools" / "fit_machine.py")
    spec = importlib.util.spec_from_file_location("fit_machine", str(path))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


_FIT_MACHINE = None


def _fit_machine():
    global _FIT_MACHINE
    if _FIT_MACHINE is None:
        _FIT_MACHINE = _load_fit_machine()
    return _FIT_MACHINE


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Trip thresholds and budgets for :class:`ElasticReplanner`.

    ``drift_ratio`` — a series trips when its geomean measured/predicted
    ratio is at or above this (or at or below its reciprocal: a model
    that is badly *pessimistic* also mis-ranks schedules).
    ``min_records`` — ignore series with fewer records (warmup noise).
    ``straggler_events`` — detector events that trip independently of
    drift.  ``cooldown_s`` — minimum seconds between replans (suppressed
    trips are counted, not dropped silently).  ``budget_s`` — soft wall
    budget for one replan/recovery; overruns increment
    ``replan.budget_exceeded`` rather than aborting (an over-budget
    recovery still beats no recovery).  ``validate`` — the static-verifier
    mode every rebuilt plan gates on.
    """

    drift_ratio: float = 2.0
    min_records: int = 3
    straggler_events: int = 1
    cooldown_s: float = 0.0
    budget_s: float = math.inf
    validate: str = "fast"


@dataclasses.dataclass(frozen=True)
class ReplanResult:
    """What one drift-triggered replan did."""

    trips: Dict[str, str]           # series/source -> reason
    machine: object                 # the fitted Machine now in force
    fit_diag: Dict                  # fit_from_registry diagnostics
    evicted: int                    # plan-cache entries invalidated
    algorithm: Optional[str]        # auto_select's post-refit choice
    plan: Optional[object]          # rebuilt MatmulPlan (when operands given)
    duration_s: float


@dataclasses.dataclass(frozen=True)
class RecoveryResult:
    """What one device-loss recovery did."""

    g: int                          # surviving grid size
    survivors: Tuple[int, ...]
    a: object                       # resharded handles
    b: object
    assignment: object              # rebuilt, validated Assignment3D
    plan: object                    # injected steal3d plan (validated)
    evicted: int                    # dead grid's evicted plan entries
    duration_s: float


class ElasticReplanner:
    """Drift/straggler-triggered re-fit + re-selection, and mesh-shrink
    recovery, over the live plan layer.

    ``machine`` is the fit base (arith peak / mem bw stay; net_bw and
    hop_latency are re-fitted) — defaults to the current drift baseline.
    ``detector`` optionally wires a :class:`~repro.runtime.fault.
    StragglerDetector` in: its events trip replanning even before the
    drift series accumulates.  Thread-compatible with serving: the engine
    calls :meth:`should_replan` / :meth:`refit` between batch boundaries
    (see ``repro.serving.ServeEngine``).
    """

    def __init__(self, *, machine=None, config: Optional[ReplanConfig] = None,
                 detector=None):
        from repro.core import roofline

        self.config = config or ReplanConfig()
        self.machine = machine or roofline.TPU_V5E
        self.detector = detector
        self.replans = 0
        self.recoveries = 0
        self._last_replan: Optional[float] = None

    # ------------------------------------------------------------- triggers
    def should_replan(self) -> Dict[str, str]:
        """Tripped signals, ``{series_or_source: reason}`` (empty = healthy).

        Reads ``obs.drift_report()`` (per-series geomean ratios) and the
        attached detector's event log.  Respects the cooldown: trips
        inside it return empty and count ``replan.suppressed_cooldown``.
        """
        from repro import obs

        cfg = self.config
        trips: Dict[str, str] = {}
        for series, stats in obs.drift_report().items():
            if stats["n"] < cfg.min_records:
                continue
            ratio = stats["ratio"]
            if ratio >= cfg.drift_ratio or ratio <= 1.0 / cfg.drift_ratio:
                trips[series] = (f"drift ratio {ratio:.3g} past "
                                 f"{cfg.drift_ratio:g} over {stats['n']} "
                                 "records")
        if self.detector is not None and \
                len(self.detector.events) >= cfg.straggler_events:
            ev = self.detector.events[-1]
            trips["straggler"] = (
                f"{len(self.detector.events)} straggler event(s), last at "
                f"step {ev['step']} ({ev['dt']:.3g}s vs mean "
                f"{ev['mean']:.3g}s)")
        if trips and self._last_replan is not None and \
                time.monotonic() - self._last_replan < cfg.cooldown_s:
            obs.registry().counter("replan.suppressed_cooldown").inc()
            return {}
        if trips:
            obs.registry().counter("replan.triggered").inc()
        return trips

    # ---------------------------------------------------------------- refit
    def refit(self, trips: Optional[Dict[str, str]] = None):
        """Re-fit the machine from the live drift series and invalidate the
        tripped algorithms' cached plans.

        Returns ``(fitted_machine, diagnostics, evicted)``.  The fitted
        machine becomes the new drift baseline (``api.set_drift_machine``)
        and the new fit base for subsequent refits; the consumed drift
        series is reset so stale pre-fit records can't re-trip.
        """
        from repro import obs
        from repro.core import api

        with obs.span("replan.refit", trips=len(trips or ())):
            fitted, diag = _fit_machine().fit_from_registry(
                base=self.machine)
            tripped_algs = {s.split("/")[0] for s in (trips or ())
                            if s != "straggler"}
            evicted = 0
            for alg in sorted(tripped_algs):
                if alg in api.REGISTRY:
                    evicted += api.invalidate_plans(algorithm=alg)
            api.set_drift_machine(fitted)
            obs.reset_drift()
        self.machine = fitted
        reg = obs.registry()
        reg.counter("replan.refits").inc()
        if evicted:
            reg.counter("replan.plans_evicted").inc(evicted)
        return fitted, diag, evicted

    # --------------------------------------------------------------- replan
    def replan(self, a=None, b=None, *, trips: Optional[Dict] = None,
               mesh=None, **plan_kw) -> ReplanResult:
        """One full drift-triggered replan: refit, evict, re-select.

        ``trips`` defaults to :meth:`should_replan` (pass explicitly to
        force).  With operand handles, the post-refit ``auto_select``
        choice is built into a plan (``algorithm="auto"`` under the
        fitted machine, gated on ``config.validate``); without them only
        the refit/eviction happens — plans rebuild lazily on the next
        cache miss, which is how the serving engine uses it between
        batches.
        """
        from repro import obs
        from repro.core import api

        cfg = self.config
        t0 = time.monotonic()
        if trips is None:
            trips = self.should_replan()
        with obs.span("replan.replan", trips=len(trips)):
            fitted, diag, evicted = self.refit(trips)
            algorithm = plan = None
            if a is not None and b is not None:
                plan = api.plan_matmul(
                    a, b, algorithm="auto", machine=fitted, mesh=mesh,
                    validate=cfg.validate, **plan_kw)
                algorithm = plan.algorithm.name
        dt = time.monotonic() - t0
        self.replans += 1
        self._last_replan = time.monotonic()
        reg = obs.registry()
        reg.histogram("replan.duration_s").observe(dt)
        if dt > cfg.budget_s:
            reg.counter("replan.budget_exceeded").inc()
        return ReplanResult(trips=dict(trips), machine=fitted,
                            fit_diag=diag, evicted=evicted,
                            algorithm=algorithm, plan=plan, duration_s=dt)

    # ------------------------------------------------------------- recovery
    def recover_from_loss(self, a, b, survivors, *, mesh=None,
                          algorithm: str = "steal3d", wire: str = "padded",
                          locality: str = "locality",
                          comm_penalty: float = 1.0,
                          max_g: Optional[int] = None,
                          capacity="bucket", **plan_kw) -> RecoveryResult:
        """Rebuild the multiply on the surviving mesh, gated statically.

        Steps: pick the new grid, drop the dead grid's cached plans,
        reshard both handles device-side, rebuild the steal3d assignment
        for the survivors from the resharded operand's real item costs,
        prove survivor coverage, and build the injected plan under
        ``config.validate`` (default ``"fast"``).  Raises
        ``PlanValidationError`` / ``ValueError`` before anything runs if
        the rebuilt schedule is not provably correct.
        """
        from repro import analysis, obs
        from repro.core import api
        from repro.core import schedule as _schedule

        from .elastic import choose_grid_shape

        cfg = self.config
        survivors = (tuple(range(survivors)) if isinstance(survivors, int)
                     else tuple(survivors))
        t0 = time.monotonic()
        g_old = a.g
        g = choose_grid_shape(survivors, max_g=max_g)
        with obs.span("replan.recover", g_old=g_old, g_new=g,
                      survivors=len(survivors)):
            evicted = api.invalidate_plans(g=g_old) if g != g_old else 0
            a2 = api.reshard(a, g, capacity=capacity)
            if isinstance(b, api.DistDense):
                # the RHS re-pads its inner dim against the resharded A's
                # padding, exactly like first-time construction
                m, n = b.logical_shape
                b2 = api.DistDense.for_rhs(b.data[:m, :n], a2,
                                           allow_pad=True)
            else:
                b2 = api.reshard(b, g, capacity=capacity)
            # Rebuild the stealing equilibrium from the resharded
            # operand's actual per-item costs (real block products per
            # (i, k) panel tile for sparse A, uniform for dense), the
            # same grid build_steal_plan validates the injection against.
            if isinstance(a2, api.DistBSR):
                cost_ik = np.asarray(a2.grid_structure().real.sum(axis=2),
                                     dtype=np.float64)
            else:
                cost_ik = np.ones((g, g), dtype=np.float64)
            asg = _schedule.assign_3d_lpt(
                np.broadcast_to(cost_ik[:, :, None], (g, g, g)).copy(), g,
                locality=locality, comm_penalty=comm_penalty)
            findings = analysis.check_survivor_coverage(asg, g, survivors)
            if findings:
                raise analysis.PlanValidationError(findings)
            plan = api.plan_matmul(a2, b2, algorithm=algorithm, mesh=mesh,
                                   wire=wire, assignment=asg,
                                   validate=cfg.validate, **plan_kw)
        dt = time.monotonic() - t0
        self.recoveries += 1
        reg = obs.registry()
        reg.counter("replan.recoveries").inc()
        reg.histogram("replan.recovery_s").observe(dt)
        if dt > cfg.budget_s:
            reg.counter("replan.budget_exceeded").inc()
        return RecoveryResult(g=g, survivors=survivors, a=a2, b=b2,
                              assignment=asg, plan=plan, evicted=evicted,
                              duration_s=dt)
