"""Elastic mesh sizing: pick the best mesh for however many chips survive.

When a pod loses nodes, the job restarts on the remaining chip count; this
module picks the closest-to-square (data, model) factorization subject to
divisibility constraints (model axis must divide heads/experts), and the
checkpoint manager re-shards state onto the new mesh (see ckpt/checkpoint).
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple, Union

__all__ = ["choose_mesh_shape", "choose_grid_shape"]


def choose_mesh_shape(n_chips: int, *, model_divisors: Tuple[int, ...] = (),
                      max_model: int = 64,
                      prefer_model: Optional[int] = None) -> Tuple[int, int]:
    """Return (data, model) with data*model == usable_chips (largest usable).

    ``model_divisors``: the model axis must divide all of these (heads,
    kv-heads, experts...).  Prefers the largest model axis <= max_model that
    satisfies constraints, then the squarest data split.
    """
    def ok_model(m: int) -> bool:
        if m > max_model:
            return False
        return all(d % m == 0 for d in model_divisors if d)

    best = None  # (model, use)
    # allow shaving chips (failed nodes) down to 87.5% utilization; scan
    # the whole shave range — a slightly smaller chip count often admits
    # a much larger model axis (e.g. 250 chips force model<=2, 248 allow 8)
    for use in range(n_chips, max(1, int(n_chips * 0.875)) - 1, -1):
        cands = [m for m in range(1, use + 1) if use % m == 0 and ok_model(m)]
        if not cands:
            continue
        if prefer_model and prefer_model in cands:
            return (use // prefer_model, prefer_model)
        m = max(cands)
        if best is None or m > best[0]:
            best = (m, use)
    if best is None:
        raise ValueError(f"no usable mesh for {n_chips} chips "
                         f"with divisors {model_divisors}")
    m, use = best
    return (use // m, m)


def choose_grid_shape(survivors: Union[int, Iterable[int]], *,
                      max_g: Optional[int] = None) -> int:
    """Largest ``g`` such that a g x g matmul grid fits on the survivors.

    The sparse engine's schedules (SUMMA / rings / steal3d) all run on a
    square ``g x g`` mesh, so after device loss the recovery grid is the
    largest square that fits the surviving device count.  ``survivors``
    is either a count or the surviving device-id collection (what
    :class:`repro.runtime.faultinject.DeviceLoss` yields); ``max_g``
    optionally caps the result (e.g. at the pre-loss grid size).
    """
    n = survivors if isinstance(survivors, int) else len(tuple(survivors))
    if n < 1:
        raise ValueError(f"need at least one surviving device, got {n}")
    g = int(n ** 0.5)
    while (g + 1) * (g + 1) <= n:   # int(sqrt) can round down under fp error
        g += 1
    while g * g > n:
        g -= 1
    if max_g is not None:
        g = min(g, max_g)
    return max(g, 1)
