"""Elastic mesh sizing: pick the best mesh for however many chips survive.

When a pod loses nodes, the job restarts on the remaining chip count; this
module picks the closest-to-square (data, model) factorization subject to
divisibility constraints (model axis must divide heads/experts), and the
checkpoint manager re-shards state onto the new mesh (see ckpt/checkpoint).
"""
from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["choose_mesh_shape"]


def choose_mesh_shape(n_chips: int, *, model_divisors: Tuple[int, ...] = (),
                      max_model: int = 64,
                      prefer_model: Optional[int] = None) -> Tuple[int, int]:
    """Return (data, model) with data*model == usable_chips (largest usable).

    ``model_divisors``: the model axis must divide all of these (heads,
    kv-heads, experts...).  Prefers the largest model axis <= max_model that
    satisfies constraints, then the squarest data split.
    """
    def ok_model(m: int) -> bool:
        if m > max_model:
            return False
        return all(d % m == 0 for d in model_divisors if d)

    best = None
    # allow shaving chips (failed nodes) down to 87.5% utilization
    for use in range(n_chips, max(1, int(n_chips * 0.875)) - 1, -1):
        cands = [m for m in range(1, use + 1) if use % m == 0 and ok_model(m)]
        if not cands:
            continue
        if prefer_model and prefer_model in cands:
            m = prefer_model
        else:
            m = max(cands)
        best = (use // m, m)
        break
    if best is None:
        raise ValueError(f"no usable mesh for {n_chips} chips "
                         f"with divisors {model_divisors}")
    return best
