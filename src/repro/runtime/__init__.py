from .fault import StragglerDetector, RestartableLoop, PreemptionSignal  # noqa: F401
from .elastic import choose_mesh_shape  # noqa: F401
