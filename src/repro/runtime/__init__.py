from .fault import StragglerDetector, RestartableLoop, PreemptionSignal  # noqa: F401
from .elastic import choose_mesh_shape  # noqa: F401
from . import platform  # noqa: F401
from .platform import set_platform, set_host_device_count  # noqa: F401
