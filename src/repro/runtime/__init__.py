from .fault import StragglerDetector, RestartableLoop, PreemptionSignal  # noqa: F401
from .elastic import choose_mesh_shape, choose_grid_shape  # noqa: F401
from .faultinject import (  # noqa: F401
    StragglerInjector, TransientFailure, DeviceLoss, record_straggler_drift,
)
from . import platform  # noqa: F401
from .platform import set_platform, set_host_device_count  # noqa: F401
