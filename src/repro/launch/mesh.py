"""Production mesh construction + sharding helpers.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import make_mesh
from ..runtime import platform as _platform

__all__ = ["make_production_mesh", "filter_spec", "shardings_for",
           "batch_partition_spec"]


def make_production_mesh(*, multi_pod: bool = False, overlap: bool = True):
    """Build the production device mesh.

    ``overlap=True`` (default) first plants the async-collective /
    latency-hiding XLA flags through ``repro.runtime.platform`` — the
    runtime half of the split-step double-buffered schedule bodies.
    Safe mid-process: skipped silently once a jax backend has
    initialized (flags could no longer take effect).
    """
    if overlap and not _platform.jax_initialized():
        _platform.set_platform(overlap=True)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def filter_spec(spec: P, mesh) -> P:
    """Drop mesh axes a spec references that this mesh doesn't have
    (e.g. 'pod' on the single-pod mesh)."""
    names = set(mesh.axis_names)
    fixed = []
    for s in spec:
        if s is None:
            fixed.append(None)
        elif isinstance(s, tuple):
            keep = tuple(a for a in s if a in names)
            fixed.append(keep if keep else None)
        else:
            fixed.append(s if s in names else None)
    return P(*fixed)


def shardings_for(spec_tree, mesh):
    """Pytree of PartitionSpec -> pytree of NamedSharding on ``mesh``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, filter_spec(s, mesh)),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def sanitize_spec(spec: P, shape, mesh) -> P:
    """filter_spec + drop axes whose size doesn't divide the array dim."""
    sizes = dict(mesh.shape)
    fixed = []
    for i, s in enumerate(filter_spec(spec, mesh)):
        dim = shape[i] if i < len(shape) else 1
        if s is None:
            fixed.append(None)
        elif isinstance(s, tuple):
            pick, prod = [], 1
            for a in s:
                if dim % (prod * sizes[a]) == 0:
                    pick.append(a)
                    prod *= sizes[a]
            fixed.append(tuple(pick) if pick else None)
        else:
            fixed.append(s if dim % sizes[s] == 0 else None)
    return P(*fixed)


def sanitized_shardings(spec_tree, abstract_tree, mesh):
    """NamedShardings with per-dimension divisibility filtering."""
    def one(s, x):
        return NamedSharding(mesh, sanitize_spec(s, x.shape, mesh))
    return jax.tree.map(
        one, spec_tree, abstract_tree,
        is_leaf=lambda s: isinstance(s, P))


def batch_partition_spec(batch_size: int, mesh,
                         trailing: Tuple = ()) -> P:
    """Shard the batch dim over ('pod','data') when divisible, else leave it
    unsharded (batch-1 long-context decode)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and batch_size % size == 0:
        return P(axes, *trailing)
    return P(None, *trailing)
