import os

from repro.runtime.platform import set_host_device_count

# Must run before the first jax backend init (jax locks the device count
# then) — runtime.platform is the repo's single XLA_FLAGS write site.
# REPRO_DRYRUN_DEVICES overrides the full-pod fake count for quick local
# runs.
set_host_device_count(int(os.environ.get("REPRO_DRYRUN_DEVICES", 512)))

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell this lowers + compiles
the real step function against ShapeDtypeStruct inputs (no allocation),
prints ``memory_analysis()`` / ``cost_analysis()``, and extracts the
roofline terms (compute / memory / collective) from the optimized HLO via
``launch/hlo_analysis.py``.  Results are cached as JSON per cell so the
sweep is restartable.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

__all__ = ["run_cell", "input_specs", "main"]

# TPU v5e constants (per harness): bf16 peak, HBM bw, ICI per-link bw.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _lazy_imports():
    import jax  # noqa
    global jax, jnp, NamedSharding, P, get_config, SHAPES, cell_supported
    global tf, lm, AdamW, mesh_mod, hlo_analysis, make_batch_specs
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, SHAPES, cell_supported
    from repro.models import transformer as tf
    from repro.models import lm
    from repro.optim import AdamW
    from repro.launch import mesh as mesh_mod
    from repro.launch import hlo_analysis
    from repro.data.pipeline import make_batch_specs


def input_specs(cfg, shape, mesh) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding

    from repro.launch.mesh import batch_partition_spec, shardings_for
    from repro.data.pipeline import make_batch_specs
    from jax.sharding import PartitionSpec as P

    batch, seq = shape.batch, shape.seq
    bspec = batch_partition_spec(batch, mesh)

    def sds(shp, dt, spec):
        return jax.ShapeDtypeStruct(
            shp, dt, sharding=NamedSharding(mesh, spec))

    if shape.kind == "train":
        raw = make_batch_specs(cfg, batch, seq)
        out = {}
        for k, (shp, dt) in raw.items():
            spec = P(bspec[0], *([None] * (len(shp) - 1)))
            out[k] = sds(shp, jnp.dtype(dt), spec)
        return out
    if shape.kind == "prefill":
        # a prompt of exactly `seq` tokens (no label shift!) — a +1 here once
        # made every chunked kernel degenerate to per-token scans (§Perf)
        if cfg.frontend == "audio":
            return {"frames": sds((batch, seq, cfg.frontend_dim),
                                  jnp.float32, P(bspec[0], None, None))}
        out = {}
        text = seq - (cfg.num_patches if cfg.frontend == "vlm" else 0)
        out["tokens"] = sds((batch, text), jnp.int32, P(bspec[0], None))
        if cfg.frontend == "vlm":
            out["patches"] = sds(
                (batch, cfg.num_patches, cfg.frontend_dim), jnp.float32,
                P(bspec[0], None, None))
        return out
    # decode: one token step with a cache of length shape.seq
    tok = sds((batch, 1), jnp.int32, P(bspec[0], None))
    return {"tokens": tok}


def _abstract_params(cfg):
    import jax
    from repro.models import transformer as tf
    return jax.eval_shape(lambda k: tf.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def _with_shardings(abstract_tree, spec_tree, mesh):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import sanitize_spec

    def attach(s, x):
        return jax.ShapeDtypeStruct(
            x.shape, x.dtype,
            sharding=NamedSharding(mesh, sanitize_spec(s, x.shape, mesh)))

    return jax.tree.map(attach, spec_tree, abstract_tree,
                        is_leaf=lambda s: isinstance(s, P))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_path: Optional[str] = None, verbose: bool = True) -> Dict:
    """Lower+compile one (arch, shape, mesh) cell; return the record dict."""
    _lazy_imports()
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cfg.moe is not None:
        import dataclasses as _dc
        batch_shards = 32 if mesh_kind == "multi" else 16
        cfg = _dc.replace(
            cfg, moe_dispatch_groups=batch_shards,
            moe_impl=os.environ.get("REPRO_MOE_IMPL", cfg.moe_impl))
    ok, reason = cell_supported(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "time": time.time()}
    if not ok:
        rec.update(status="skipped", reason=reason)
        _dump(rec, out_path, verbose)
        return rec

    debug_mesh = os.environ.get("REPRO_DRYRUN_MESH")
    if debug_mesh:  # e.g. "4,4" or "2,4,4" — local debugging only
        import jax as _jax
        shape_ = tuple(int(x) for x in debug_mesh.split(","))
        axes_ = ("pod", "data", "model")[-len(shape_):]
        from repro.compat import make_mesh as _make_mesh
        mesh = _make_mesh(shape_, axes_)
    else:
        mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    pspecs = tf.param_specs(cfg)
    params_sds = _with_shardings(_abstract_params(cfg), pspecs, mesh)
    param_sh = jax.tree.map(lambda x: x.sharding, params_sds,
                            is_leaf=lambda x: isinstance(
                                x, jax.ShapeDtypeStruct))

    from repro.compat import set_mesh

    t0 = time.time()
    try:
        with set_mesh(mesh):
            if shape.kind == "train":
                opt = AdamW(lr=1e-4)
                opt_specs = AdamW.state_specs(pspecs)
                opt_sds = _with_shardings(
                    jax.eval_shape(opt.init, params_sds), opt_specs, mesh)
                opt_sh = jax.tree.map(lambda x: x.sharding, opt_sds,
                                      is_leaf=lambda x: isinstance(
                                          x, jax.ShapeDtypeStruct))
                batch_sds = input_specs(cfg, shape, mesh)
                step = lm.make_train_step(cfg, opt)
                metr_sh = {k: NamedSharding(mesh, P()) for k in
                           ("loss", "aux", "dropped", "grad_norm")}
                jitted = jax.jit(
                    step,
                    in_shardings=(param_sh, opt_sh,
                                  jax.tree.map(lambda x: x.sharding,
                                               batch_sds)),
                    out_shardings=(param_sh, opt_sh, metr_sh),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(params_sds, opt_sds, batch_sds)
            elif shape.kind == "prefill":
                batch_sds = input_specs(cfg, shape, mesh)

                if cfg.is_encoder:
                    # encoders have no decode cache: "prefill" = one forward
                    def prefill_fn(params, batch):
                        logits, _, _ = tf.forward(params, batch, cfg)
                        return logits
                else:
                    def prefill_fn(params, batch):
                        return lm.prefill(params, batch, cfg,
                                          max_len=shape.seq)

                jitted = jax.jit(
                    prefill_fn,
                    in_shardings=(param_sh,
                                  jax.tree.map(lambda x: x.sharding,
                                               batch_sds)))
                lowered = jitted.lower(params_sds, batch_sds)
            else:  # decode
                batch_sds = input_specs(cfg, shape, mesh)
                cache_specs_tree = tf.cache_specs(cfg)
                cache_abs = jax.eval_shape(
                    lambda: tf.init_cache(cfg, shape.batch, shape.seq))
                cache_sds = _with_shardings(cache_abs, cache_specs_tree, mesh)
                cache_sh = jax.tree.map(lambda x: x.sharding, cache_sds,
                                        is_leaf=lambda x: isinstance(
                                            x, jax.ShapeDtypeStruct))
                pos_sds = jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, P()))
                step = lm.make_decode_step(cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(param_sh,
                                  batch_sds["tokens"].sharding,
                                  cache_sh, NamedSharding(mesh, P())),
                    donate_argnums=(2,))
                lowered = jitted.lower(params_sds, batch_sds["tokens"],
                                       cache_sds, pos_sds)

            compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        _dump(rec, out_path, verbose)
        return rec
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_rec = {}
    if mem is not None:
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            mem_rec[field] = getattr(mem, field, None)
    cost_rec = {}
    if cost:
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in cost:
                cost_rec[k] = cost[k]

    hlo = None
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    if out_path:  # keep the optimized HLO for offline re-analysis
        import gzip
        with gzip.open(out_path.replace(".json", "") + ".hlo.gz", "wt") as f:
            f.write(hlo)
    stats = hlo_analysis.analyze_hlo(hlo)

    # ----- roofline terms (per-chip, seconds) -------------------------------
    # HLO stats are whole-program; per-chip = /n_chips for SPMD-partitioned
    # modules (the compiled module is already per-device after GSPMD).
    compute_s = stats.dot_flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes_fused / HBM_BW
    collective_s = stats.total_collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    model_flops = _model_flops(cfg, shape)
    rec.update(
        status="ok",
        n_chips=n_chips,
        compile_seconds=round(t_compile, 1),
        memory_analysis=mem_rec,
        cost_analysis=cost_rec,
        hlo_stats={
            "dot_flops": stats.dot_flops,
            "hbm_bytes": stats.hbm_bytes,
            "hbm_bytes_fused": stats.hbm_bytes_fused,
            "collective_bytes": stats.collective_bytes,
            "collective_count": stats.collective_count,
        },
        roofline={**terms, "bottleneck": bottleneck,
                  "model_flops": model_flops,
                  "useful_flops_ratio": (
                      model_flops / (stats.dot_flops * n_chips)
                      if stats.dot_flops else None)},
    )
    _dump(rec, out_path, verbose)
    return rec


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D=batch x 1."""
    n = cfg.active_param_count()
    n_emb = cfg.vocab_size * cfg.d_model
    n_body = max(n - n_emb * (1 if cfg.tie_embeddings else 2), 1)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_body * tokens
    if shape.kind == "prefill":
        return 2.0 * n_body * shape.batch * shape.seq
    return 2.0 * n_body * shape.batch  # one token per sequence


def _dump(rec: Dict, out_path: Optional[str], verbose: bool):
    if out_path:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2, default=float)
    if verbose:
        status = rec.get("status")
        if status == "ok":
            r = rec["roofline"]
            print(f"[ok] {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh']:6s} compile={rec['compile_seconds']}s "
                  f"compute={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}")
            if rec.get("memory_analysis"):
                print(f"     memory_analysis: {rec['memory_analysis']}")
            if rec.get("cost_analysis"):
                print(f"     cost_analysis: {rec['cost_analysis']}")
        elif status == "skipped":
            print(f"[skip] {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh']:6s} -- {rec['reason']}")
        else:
            print(f"[ERR] {rec['arch']:24s} {rec['shape']:12s} "
                  f"{rec['mesh']:6s} -- {rec.get('error')}")
            if rec.get("traceback"):
                print(rec["traceback"])


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="single",
                   choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true",
                   help="sweep every supported (arch x shape) cell")
    p.add_argument("--out-dir", default="results/dryrun")
    p.add_argument("--force", action="store_true",
                   help="recompute cells with existing JSON")
    args = p.parse_args(argv)
    _lazy_imports()
    from repro.configs import list_archs

    os.makedirs(args.out_dir, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = list_archs()
        shapes = list(SHAPES)
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        archs, shapes = [args.arch], [args.shape]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                out = os.path.join(
                    args.out_dir,
                    f"{arch}__{shape_name}__{mesh_kind}.json")
                if not args.force and os.path.exists(out):
                    with open(out) as f:
                        rec = json.load(f)
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {shape_name} {mesh_kind} "
                              f"({rec['status']})")
                        continue
                rec = run_cell(arch, shape_name, mesh_kind, out)
                if rec.get("status") == "error":
                    failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
