"""Multi-device correctness self-test (run as a subprocess).

Sets ``XLA_FLAGS`` *before* importing jax, builds a small host-device mesh,
and checks the distributed algorithms against dense references.  Used by
``tests/test_distributed.py`` and as a launch-time preflight on real
clusters (a node that fails its self-test is drained before training
starts — part of the fault-tolerance story).

Usage:  python -m repro.launch.selftest --devices 4 --check all
"""
from __future__ import annotations

import argparse
import os
import sys


def _parse():
    p = argparse.ArgumentParser()
    p.add_argument("--devices", type=int, default=4)
    p.add_argument("--check", default="all",
                   choices=["all", "spmm", "spgemm", "dense", "moe",
                            "train_parallel"])
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args()


def main() -> int:
    args = _parse()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))
    import jax  # noqa: E402  (after XLA_FLAGS)
    import jax.numpy as jnp
    import numpy as np

    from repro.core.bsr import TiledBSR, random_sparse
    from repro.core.grid import ProcessGrid
    from repro.core import spmm as dspmm
    from repro.core.dist import make_grid_mesh

    needs_grid = args.check in ("all", "dense", "spmm", "spgemm")
    g = int(np.sqrt(args.devices))
    mesh = None
    if needs_grid:
        assert g * g == args.devices, "grid checks need a square device count"
        mesh = make_grid_mesh(g)
    rng = np.random.default_rng(args.seed)
    failures = []

    def check(name, got, want, tol=1e-4):
        err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
        ok = err <= tol
        print(f"  [{'ok' if ok else 'FAIL'}] {name:28s} max|err|={err:.3e}")
        if not ok:
            failures.append(name)

    if args.check in ("all", "dense"):
        print(f"== dense_matmul on {g}x{g} mesh ==")
        a = rng.standard_normal((24, 20)).astype(np.float32)
        b = rng.standard_normal((20, 12)).astype(np.float32)
        want = a @ b
        for alg in dspmm.ALGORITHMS:
            got = dspmm.dense_matmul(jnp.asarray(a), jnp.asarray(b), g=g,
                                     mesh=mesh, algorithm=alg)
            check(f"dense/{alg}", got, want)

    if args.check in ("all", "spmm"):
        print(f"== spmm on {g}x{g} mesh ==")
        bs = 4
        a_d = random_sparse(32, 32, 0.2, seed=args.seed)
        b = rng.standard_normal((32, 8)).astype(np.float32)
        grid = ProcessGrid(g, g)
        a_t = TiledBSR.from_dense(a_d, grid, block_size=bs)
        want = a_d @ b
        for alg in dspmm.ALGORITHMS:
            got = dspmm.spmm(a_t, jnp.asarray(b), mesh=mesh, algorithm=alg,
                             impl="ref")
            check(f"spmm/{alg}", got, want)
        # Pallas interpret path through the distributed ring
        got = dspmm.spmm(a_t, jnp.asarray(b), mesh=mesh, algorithm="ring_c",
                         impl="interpret")
        check("spmm/ring_c[interpret]", got, want)

    if args.check in ("all", "spgemm"):
        print(f"== spgemm on {g}x{g} mesh ==")
        bs = 4
        a_d = random_sparse(32, 32, 0.15, seed=args.seed + 1)
        b_d = random_sparse(32, 32, 0.2, seed=args.seed + 2)
        grid = ProcessGrid(g, g)
        a_t = TiledBSR.from_dense(a_d, grid, block_size=bs)
        b_t = TiledBSR.from_dense(b_d, grid, block_size=bs)
        want = a_d @ b_d
        for alg in dspmm.ALGORITHMS:
            got = dspmm.spgemm(a_t, b_t, mesh=mesh, algorithm=alg, impl="ref")
            check(f"spgemm/{alg}", got, want)

    if args.check in ("all", "moe"):
        print("== MoE dispatch/combine vs dense ==")
        from repro.models import moe as moe_mod
        ok = moe_mod.selftest_distributed(args.devices)
        print(f"  [{'ok' if ok else 'FAIL'}] moe/expert_parallel")
        if not ok:
            failures.append("moe")
        ok = moe_mod.selftest_ring(args.devices)
        print(f"  [{'ok' if ok else 'FAIL'}] moe/ring_dispatch")
        if not ok:
            failures.append("moe_ring")

    if args.check in ("all", "train_parallel"):
        print("== data/tensor-parallel train step equivalence ==")
        from repro.launch.train import selftest_parallel_equivalence
        ok = selftest_parallel_equivalence(args.devices)
        print(f"  [{'ok' if ok else 'FAIL'}] train/dp_tp_equivalence")
        if not ok:
            failures.append("train_parallel")

    if failures:
        print(f"SELFTEST FAILED: {failures}")
        return 1
    print("SELFTEST PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
