"""Multi-device correctness self-test (run as a subprocess).

Plants the fake-device XLA flags (via ``repro.runtime.platform``) *before*
the first jax backend init, builds a small host-device mesh,
and checks the distributed algorithms against dense references.  Used by
``tests/test_distributed.py`` and as a launch-time preflight on real
clusters (a node that fails its self-test is drained before training
starts — part of the fault-tolerance story).

All distributed-matmul checks go through the plan-based API
(:mod:`repro.core.api`); the ``api`` check additionally verifies plan/
placement reuse (no re-trace, skew applied once) and that the deprecated
``core.spmm`` shims are bit-identical to the planned path.

Usage:  python -m repro.launch.selftest --devices 4 --check all
"""
from __future__ import annotations

import argparse
import sys


def _parse():
    p = argparse.ArgumentParser()
    p.add_argument("--devices", type=int, default=4)
    p.add_argument("--check", default="all",
                   choices=["all", "spmm", "spgemm", "spgemm_sparse",
                            "dense", "api", "balance", "steal3d", "wire",
                            "moe", "train_parallel", "obs", "analysis",
                            "elastic"])
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args()


def main() -> int:
    args = _parse()
    from repro.runtime.platform import set_host_device_count
    set_host_device_count(args.devices, overlap=True)
    import jax  # noqa: E402  (after flag setup)
    import jax.numpy as jnp
    import numpy as np

    from repro.core import api
    from repro.core.api import DistBSR, DistDense
    from repro.core.bsr import random_sparse
    from repro.core.dist import make_grid_mesh

    needs_grid = args.check in ("all", "dense", "spmm", "spgemm",
                                "spgemm_sparse", "api", "balance",
                                "steal3d", "wire", "analysis")
    g = int(np.sqrt(args.devices))
    mesh = None
    if needs_grid:
        assert g * g == args.devices, "grid checks need a square device count"
        mesh = make_grid_mesh(g)
    rng = np.random.default_rng(args.seed)
    failures = []

    def check(name, got, want, tol=1e-4):
        err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
        ok = err <= tol
        print(f"  [{'ok' if ok else 'FAIL'}] {name:28s} max|err|={err:.3e}")
        if not ok:
            failures.append(name)

    def check_flag(name, ok):
        print(f"  [{'ok' if ok else 'FAIL'}] {name}")
        if not ok:
            failures.append(name)

    if args.check in ("all", "dense"):
        print(f"== dense matmul on {g}x{g} mesh ==")
        # odd shapes exercise the shared pad/crop epilogue on the dense path
        a = rng.standard_normal((23, 19)).astype(np.float32)
        b = rng.standard_normal((19, 11)).astype(np.float32)
        want = a @ b
        for alg in api.algorithms():
            got = api.matmul(jnp.asarray(a), jnp.asarray(b), g=g, mesh=mesh,
                             algorithm=alg)
            check(f"dense/{alg}", got, want)

    if args.check in ("all", "spmm"):
        print(f"== spmm on {g}x{g} mesh ==")
        a_d = random_sparse(32, 32, 0.2, seed=args.seed)
        b = rng.standard_normal((32, 8)).astype(np.float32)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        want = a_d @ b
        for alg in api.algorithms():
            got = api.matmul(a_h, b_h, mesh=mesh, algorithm=alg, impl="ref")
            check(f"spmm/{alg}", got, want)
        # Pallas interpret path through the distributed ring
        got = api.matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                         impl="interpret")
        check("spmm/ring_c[interpret]", got, want)

    if args.check in ("all", "spgemm"):
        print(f"== spgemm on {g}x{g} mesh ==")
        a_d = random_sparse(32, 32, 0.15, seed=args.seed + 1)
        b_d = random_sparse(32, 32, 0.2, seed=args.seed + 2)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistBSR.from_dense(b_d, g=g, block_size=4)
        want = a_d @ b_d
        for alg in api.algorithms():
            got = api.matmul(a_h, b_h, mesh=mesh, algorithm=alg, impl="ref")
            check(f"spgemm/{alg}", got, want)

    if args.check in ("all", "spgemm_sparse"):
        print(f"== sparse-output spgemm on {g}x{g} mesh ==")
        a_d = random_sparse(32, 32, 0.15, seed=args.seed + 4)
        b_d = random_sparse(32, 32, 0.2, seed=args.seed + 5)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistBSR.from_dense(b_d, g=g, block_size=4)
        want = a_d @ b_d
        for alg in api.sparse_algorithms():
            c = api.matmul(a_h, b_h, mesh=mesh, algorithm=alg, impl="ref",
                           output="sparse")
            check(f"spgemm_sparse/{alg}", c.densify(), want)
        check_flag("spgemm_sparse/returns_handle",
                   isinstance(api.matmul(a_h, b_h, mesh=mesh,
                                         algorithm="ring_c", impl="ref",
                                         output="sparse"), DistBSR))
        # chained cube stays packed: the product handle is the operand
        c2 = api.matmul(a_h, a_h, mesh=mesh, algorithm="ring_c", impl="ref",
                        output="sparse")
        c3 = api.matmul(c2, a_h, mesh=mesh, algorithm="ring_c", impl="ref",
                        output="sparse")
        check("spgemm_sparse/chain_cube", c3.densify(), a_d @ a_d @ a_d,
              tol=1e-3)
        # Pallas interpret path through the packed ring
        c_i = api.matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                         impl="interpret", output="sparse")
        check("spgemm_sparse/ring_c[interpret]", c_i.densify(), want)

    if args.check in ("all", "balance"):
        print(f"== balanced tiling + auto-scheduling on {g}x{g} mesh ==")
        from repro.core.bsr import rmat_matrix
        a_d = rmat_matrix(scale=6, edgefactor=8, seed=args.seed)  # skewed
        b = rng.standard_normal((64, 8)).astype(np.float32)
        b_j = jnp.asarray(b)
        h_none = DistBSR.from_dense(a_d, g=g, block_size=4)
        h_rows = DistBSR.from_dense(a_d, g=g, block_size=4, balance="rows")
        check_flag(
            f"balance/capacity ({h_rows.capacity} <= {h_none.capacity})",
            h_rows.capacity <= h_none.capacity)
        want = a_d @ b
        b_h = DistDense.for_rhs(b_j, h_rows)
        for alg in api.algorithms():
            got = api.matmul(h_rows, b_h, mesh=mesh, algorithm=alg,
                             impl="ref")
            check(f"balance/{alg}", got, want)
        plan = api.plan_matmul(h_rows, b_h, mesh=mesh, algorithm="auto",
                               impl="ref")
        check(f"balance/auto[{plan.algorithm.name}]", plan(h_rows, b_h),
              want)
        check_flag("balance/auto_scores_recorded",
                   plan.auto_scores is not None and
                   plan.algorithm.name == min(plan.auto_scores,
                                              key=plan.auto_scores.get))

    if args.check in ("all", "steal3d"):
        print(f"== steal3d static work-grid dispatch on {g}x{g} mesh ==")
        from repro.core.bsr import rmat_matrix
        a_d = rmat_matrix(scale=6, edgefactor=8, seed=args.seed)  # skewed
        b = rng.standard_normal((64, 8)).astype(np.float32)
        b_sp = random_sparse(64, 64, 0.1, seed=args.seed + 6)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        b_sph = DistBSR.from_dense(b_sp, g=g, block_size=4)
        plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm="steal3d",
                               impl="ref")
        asg = plan.steal.assignment
        check_flag(
            f"steal3d/makespan<=owner ({asg.makespan:.0f} <= "
            f"{asg.owner_makespan:.0f}, moved={asg.n_moved})",
            asg.makespan <= asg.owner_makespan)
        check("steal3d/spmm", plan(a_h, b_h), a_d @ b)
        check("steal3d/spmm_vs_ring_c", plan(a_h, b_h),
              api.matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                         impl="ref"))
        check("steal3d/spgemm",
              api.matmul(a_h, b_sph, mesh=mesh, algorithm="steal3d",
                         impl="ref"), a_d @ b_sp)
        da = rng.standard_normal((23, 19)).astype(np.float32)
        db = rng.standard_normal((19, 11)).astype(np.float32)
        check("steal3d/dense",
              api.matmul(jnp.asarray(da), jnp.asarray(db), g=g, mesh=mesh,
                         algorithm="steal3d"), da @ db)
        # Pallas interpret path through the pooled pair-accumulate kernel
        check("steal3d/spmm[interpret]",
              api.matmul(a_h, b_h, mesh=mesh, algorithm="steal3d",
                         impl="interpret"), a_d @ b)
        # empty operand fast path (capacity 0) end-to-end (satellite)
        e_h = DistBSR.from_dense(np.zeros((64, 64), np.float32), g=g,
                                 block_size=4)
        check_flag(f"steal3d/empty_capacity_0 (cap={e_h.capacity})",
                   e_h.capacity == 0)
        check("steal3d/empty_operand",
              api.matmul(e_h, b_h, mesh=mesh, algorithm="steal3d",
                         impl="ref"), np.zeros((64, 8), np.float32))

    if args.check in ("all", "wire"):
        print(f"== packed wire format on {g}x{g} mesh ==")
        from repro.core.bsr import rmat_matrix
        a_d = rmat_matrix(scale=6, edgefactor=8, seed=args.seed)  # skewed
        b = rng.standard_normal((64, 8)).astype(np.float32)
        b_sp = random_sparse(64, 64, 0.08, seed=args.seed + 9)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        b_sph = DistBSR.from_dense(b_sp, g=g, block_size=4)
        for alg in api.algorithms():
            plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                   impl="ref", wire="packed")
            check(f"wire/spmm/{alg}[{plan.wire}]", plan(a_h, b_h), a_d @ b)
            plan_sp = api.plan_matmul(a_h, b_sph, mesh=mesh, algorithm=alg,
                                      impl="ref", wire="packed")
            check(f"wire/spgemm/{alg}[{plan_sp.wire}]", plan_sp(a_h, b_sph),
                  a_d @ b_sp)
            if plan.wire == "packed":
                pad = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm=alg,
                                      impl="ref", wire="padded")
                bp = plan.cost_model()["total_net_bytes"]
                bd = pad.cost_model()["total_net_bytes"]
                check_flag(f"wire/bytes/{alg} ({bp:.0f} <= {bd:.0f})",
                           bp <= bd)
        for alg in api.sparse_algorithms():
            plan = api.plan_matmul(a_h, b_sph, mesh=mesh, algorithm=alg,
                                   impl="ref", output="sparse")
            check_flag(f"wire/sparse_output/{alg}_auto_packs",
                       plan.wire == "packed")
            check(f"wire/sparse_output/{alg}", plan(a_h, b_sph).densify(),
                  a_d @ b_sp)
        # interpret impl drives the pallas-path kernels over packed buffers
        check("wire/spmm/ring_c[interpret]",
              api.matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                         impl="interpret", wire="packed"), a_d @ b)

    if args.check in ("all", "api"):
        print(f"== plan-based API invariants on {g}x{g} mesh ==")
        from repro.core import spmm as legacy
        a_d = random_sparse(32, 32, 0.2, seed=args.seed + 3)
        b = rng.standard_normal((32, 8)).astype(np.float32)
        b_j = jnp.asarray(b)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistDense.for_rhs(b_j, a_h)
        api.clear_plan_cache()
        plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                               impl="ref")
        outs = [plan(a_h, b_h) for _ in range(5)]
        check("api/plan_result", outs[-1], a_d @ b)
        check_flag(f"api/plan_traces_once (traces={plan.traces})",
                   plan.traces == 1)
        check_flag("api/placement_cached",
                   a_h.placed("skew_rows") is a_h.placed("skew_rows"))
        got_new = api.matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                             impl="ref")
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("ignore", DeprecationWarning)
            got_old = legacy.spmm(a_h.tiled, b_j, mesh=mesh,
                                  algorithm="ring_c", impl="ref")
        check_flag("api/shim_bit_identical",
                   bool((np.asarray(got_new) == np.asarray(got_old)).all()))
        check_flag(f"api/shared_plan_cache (size={api.plan_cache_size()})",
                   api.plan_cache_size() == 1)

    if args.check in ("all", "analysis"):
        print(f"== static plan verification on {g}x{g} mesh ==")
        import dataclasses as _dc

        from repro import analysis
        from repro.core.bsr import rmat_matrix
        a_d = rmat_matrix(scale=6, edgefactor=8, seed=args.seed)  # skewed
        b = rng.standard_normal((64, 8)).astype(np.float32)
        b_sp = random_sparse(64, 64, 0.1, seed=args.seed + 7)
        a_h = DistBSR.from_dense(a_d, g=g, block_size=4)
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        b_sph = DistBSR.from_dense(b_sp, g=g, block_size=4)
        # healthy plans across the dispatch matrix prove clean — the
        # collective-count rule only has teeth at g >= 2, so this is the
        # multi-device leg of the coverage tests
        combos = []
        for alg in api.algorithms():
            for wirem in ("padded", "packed"):
                for ov in ("off", "on"):
                    combos.append((alg, b_h, "dense", wirem, ov))
            combos.append((alg, b_sph, "dense", "padded", "off"))
        for alg in api.sparse_algorithms():
            combos.append((alg, b_sph, "sparse", "packed", "off"))
        n_findings = 0
        for alg, rhs, out, wirem, ov in combos:
            plan = api.plan_matmul(a_h, rhs, mesh=mesh, algorithm=alg,
                                   impl="ref", output=out, wire=wirem,
                                   overlap=ov)
            fs = analysis.check_plan(plan, a_h, rhs) \
                + analysis.lint_plan(plan, a_h, rhs)
            for f in fs:
                print(f"    finding [{alg}/{out}/{wirem}/ov={ov}]: {f}")
            n_findings += len(fs)
        check_flag(f"analysis/healthy_matrix_clean ({len(combos)} plans)",
                   n_findings == 0)
        # validate= plumbing: full verification passes and is memoized
        plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                               impl="ref", validate="full")
        check_flag("analysis/validate_full_passes",
                   "full" in plan._validated and "fast" in plan._validated)
        # n_msgs drift: a schedule charging the wrong message count must
        # be caught by jaxpr.collective-count (needs g >= 2: at g == 1
        # the ring perms degenerate and message groups alias)
        bad = _dc.replace(api.REGISTRY.get("ring_c"), name="bad_msgs",
                          msgs_per_step=7)
        api.REGISTRY.register(bad)
        try:
            plan = api.plan_matmul(a_h, b_h, mesh=mesh,
                                   algorithm="bad_msgs", impl="ref",
                                   cache=False)
            fs = analysis.lint_plan(plan, a_h, b_h)
            check_flag("analysis/collective_count_drift_caught",
                       any(f.rule == "jaxpr.collective-count"
                           for f in fs))
            raised = False
            try:
                api.plan_matmul(a_h, b_h, mesh=mesh, algorithm="bad_msgs",
                                impl="ref", cache=False, validate="full")
            except analysis.PlanValidationError as e:
                raised = any(f.rule == "jaxpr.collective-count"
                             for f in e.findings)
            check_flag("analysis/validate_full_raises_on_drift", raised)
        finally:
            api.REGISTRY.unregister("bad_msgs")
        # corrupted ring permutation at real grid size
        plan = api.plan_matmul(a_h, b_h, mesh=mesh, algorithm="ring_c",
                               impl="ref", cache=False)
        orig_perm = api._ring_perm
        api._ring_perm = lambda gg, sign=1: tuple(
            ((d + sign) % gg, 0) for d in range(gg))   # all -> device 0
        try:
            fs = analysis.check_plan(plan, a_h, b_h)
        finally:
            api._ring_perm = orig_perm
        check_flag("analysis/corrupt_perm_caught",
                   any(f.rule == "schedule.ppermute-bijection"
                       for f in fs))

    if args.check in ("all", "moe"):
        print("== MoE dispatch/combine vs dense ==")
        from repro.models import moe as moe_mod
        ok = moe_mod.selftest_distributed(args.devices)
        print(f"  [{'ok' if ok else 'FAIL'}] moe/expert_parallel")
        if not ok:
            failures.append("moe")
        ok = moe_mod.selftest_ring(args.devices)
        print(f"  [{'ok' if ok else 'FAIL'}] moe/ring_dispatch")
        if not ok:
            failures.append("moe_ring")

    if args.check in ("all", "obs"):
        print("== execution tracing + drift tracking ==")
        import json as _json
        import os as _os
        import tempfile as _tempfile

        from repro import obs
        a_d = random_sparse(32, 32, 0.2, seed=args.seed + 6)
        b = rng.standard_normal((32, 8)).astype(np.float32)
        a_h = DistBSR.from_dense(a_d, g=1, block_size=4)
        b_h = DistDense.for_rhs(jnp.asarray(b), a_h)
        obs.enable(clear=True)
        obs.reset_drift()
        plan = api.plan_matmul(a_h, b_h, algorithm="ring_c", impl="ref",
                               cache=False)
        for _ in range(3):
            out = plan(a_h, b_h)
        obs.disable()
        check("obs/traced_result", out, a_d @ b)
        names = {e["name"] for e in obs.events()}
        check_flag("obs/plan_build_span", "plan_build" in names)
        check_flag("obs/multiply_span", "multiply.ring_c" in names)
        fd, path = _tempfile.mkstemp(suffix=".json")
        _os.close(fd)
        try:
            obs.export_trace(path)
            with open(path) as f:
                trace = _json.load(f)
        finally:
            _os.unlink(path)
        check_flag("obs/trace_schema_valid",
                   not obs.validate_trace(trace))
        drift = obs.drift_report()
        check_flag(f"obs/drift_recorded ({len(drift)} keys)",
                   any(d["n"] >= 3 for d in drift.values()))
        check_flag("obs/disabled_is_noop",
                   obs.span("x") is obs.span("y"))

    if args.check == "elastic" or (args.check == "all" and args.devices >= 9):
        # needs 9 devices: builds its own 3x3 (pre-loss) and 2x2 meshes,
        # so it is deliberately outside the needs_grid square assertion
        print("== elastic replanning: drift re-selection + mesh shrink ==")
        assert args.devices >= 9, "elastic check needs >= 9 devices"
        import dataclasses as _dc

        from repro import obs
        from repro.core import roofline
        from repro.core.bsr import rmat_matrix
        from repro.runtime.faultinject import (DeviceLoss,
                                               record_straggler_drift)
        from repro.runtime.replan import ElasticReplanner, ReplanConfig

        # -- part 1: straggler drift trips a re-fit that flips auto_select
        a = DistDense.from_global(
            rng.standard_normal((64, 64)).astype(np.float32), 2)
        b = DistDense.from_global(
            rng.standard_normal((64, 32)).astype(np.float32), 2)
        mesh2 = make_grid_mesh(2)
        # nominal machine: optimistically fast interconnect -> a
        # bandwidth-hungry schedule wins at plan time
        base = _dc.replace(roofline.TPU_V5E, name="v5e-fastnet",
                           net_bw=roofline.TPU_V5E.net_bw * 100,
                           hop_latency=1e-9)
        obs.reset_all()
        obs.enable(clear=True)
        api.set_drift_machine(base)
        try:
            p0 = api.plan_matmul(a, b, algorithm="auto", machine=base,
                                 mesh=mesh2)
            ref = np.asarray(a.data) @ np.asarray(b.data)
            check("elastic/nominal_result", p0(a, b), ref)
            # straggling network: measured steps 8x the prediction, on two
            # algorithm series so the machine re-fit is well conditioned
            p_alt = api.plan_matmul(a, b, algorithm="summa_bcast",
                                    mesh=mesh2)
            record_straggler_drift(p0, factor=8.0, n=4, machine=base)
            record_straggler_drift(p_alt, factor=8.0, n=4, machine=base)
            rp = ElasticReplanner(machine=base,
                                  config=ReplanConfig(drift_ratio=2.0))
            trips = rp.should_replan()
            check_flag(f"elastic/drift_trips ({sorted(trips)})",
                       bool(trips))
            res = rp.replan(a, b, mesh=mesh2)
            check_flag(
                f"elastic/reselect_flips ({p0.algorithm.name} -> "
                f"{res.algorithm}, evicted={res.evicted})",
                res.algorithm != p0.algorithm.name and res.evicted > 0)
            check("elastic/replanned_result", res.plan(a, b), ref)

            # -- part 2: device loss -> grid shrink -> rebuilt steal plan
            a_d = rmat_matrix(scale=6, edgefactor=8, seed=args.seed)
            bx = rng.standard_normal((64, 48)).astype(np.float32)
            a3 = DistBSR.from_dense(a_d, g=3, block_size=4)
            b3 = DistDense.for_rhs(jnp.asarray(bx), a3)
            mesh3 = make_grid_mesh(3)
            p3 = api.plan_matmul(a3, b3, algorithm="steal3d", mesh=mesh3,
                                 validate="fast")
            want = a_d @ bx
            check("elastic/preloss_result", p3(a3, b3), want)
            loss = DeviceLoss(9, 5, seed=args.seed)
            rec = rp.recover_from_loss(a3, b3, loss.survivors(),
                                       mesh=mesh2)
            check_flag(
                f"elastic/shrink_3x3_to_2x2 (survivors="
                f"{loss.survivors()}, g={rec.g}, evicted={rec.evicted})",
                rec.g == 2 and rec.evicted > 0)
            check("elastic/recovered_result", rec.plan(rec.a, rec.b), want)
            snap = obs.registry().snapshot()
            wanted_metrics = ("replan.triggered", "replan.refits",
                              "replan.plans_evicted", "replan.recoveries")
            missing = [k for k in wanted_metrics if k not in snap]
            check_flag(f"elastic/metrics_recorded (missing={missing})",
                       not missing)
        finally:
            api.set_drift_machine(None)
            obs.disable()

    if args.check in ("all", "train_parallel"):
        print("== data/tensor-parallel train step equivalence ==")
        from repro.launch.train import selftest_parallel_equivalence
        ok = selftest_parallel_equivalence(args.devices)
        print(f"  [{'ok' if ok else 'FAIL'}] train/dp_tp_equivalence")
        if not ok:
            failures.append("train_parallel")

    if failures:
        print(f"SELFTEST FAILED: {failures}")
        return 1
    print("SELFTEST PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
