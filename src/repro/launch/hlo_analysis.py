"""Compile-time HLO profiling for the roofline analysis.

``compiled.cost_analysis()`` on the CPU backend counts each instruction
once, so anything inside a ``while`` loop (== every scanned layer) would be
undercounted.  This module parses the optimized HLO text, walks the
computation graph from ENTRY, multiplies through while-loop trip counts
(extracted from the loop-condition constants), and accumulates:

* ``collective_bytes`` per collective kind (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute), summing *operand*
  sizes as required by the §Roofline methodology;
* ``dot_flops`` — 2 x prod(output dims) x contraction size per dot;
* ``hbm_bytes`` — sum of operand+output buffer sizes of top-level (post
  fusion) instructions: fused computations touch HBM only at their
  boundaries, so this is a defensible compile-time proxy for bytes moved.

Validated in tests by comparing a scanned model against its unrolled twin.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "analyze_overlap", "scope_op_counts", "HloStats",
           "OverlapReport", "COLLECTIVE_KINDS"]

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0        # upper bound: every top-level instruction
    hbm_bytes_fused: float = 0.0  # TPU-fusion estimate: major-op boundaries
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVE_KINDS})

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HloStats", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.hbm_bytes_fused += other.hbm_bytes_fused * mult
        for k in COLLECTIVE_KINDS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_count[k] += int(other.collective_count[k] * mult)


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------
# Control-flow / aliasing plumbing: moves no HBM bytes of its own.
_PLUMBING_OPS = frozenset({
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "add-dependency",
    "opt-barrier", "partition-id", "replica-id", "custom-call",
})

# Ops that still touch HBM after TPU-grade fusion (the XLA:CPU module we
# inspect fuses far less than XLA:TPU would; standalone converts/broadcasts/
# elementwise ops almost always fuse into neighbours on TPU).  The fused
# estimate counts traffic only at these boundaries.
_MAJOR_OPS = frozenset({
    "dot", "convolution", "reduce", "reduce-window", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "sort", "copy",
    "pad", "rng", "rng-bit-generator", "iota",
} | set(COLLECTIVE_KINDS) | {k + "-start" for k in COLLECTIVE_KINDS})

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_NAME_TOKEN = re.compile(r"%?([\w.\-]+)")


def _split_computations(text: str) -> Dict[str, Tuple[List[str], bool]]:
    comps: Dict[str, Tuple[List[str], bool]] = {}
    cur_name, cur_lines, is_entry = None, [], False
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur_name is None:
            m = _COMP_HEADER.match(stripped.strip())
            if m and stripped.strip().endswith("{"):
                cur_name = m.group(1)
                is_entry = stripped.strip().startswith("ENTRY")
                cur_lines = []
        else:
            if stripped.strip() == "}":
                comps[cur_name] = (cur_lines, is_entry)
                cur_name = None
            else:
                cur_lines.append(stripped)
    return comps


def _parse_instrs(lines: List[str]) -> List[Instr]:
    out = []
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # split rest into "operand-list) , attrs" at the matching paren
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands_str = rest[:idx]
        attrs = rest[idx + 1:]
        opnames = []
        for tok in operands_str.split(","):
            tok = tok.strip()
            tm = re.match(r"^%?([\w.\-]+)$", tok)
            if tm:
                opnames.append(tm.group(1))
            else:
                # typed operand form: "f32[8,16]{1,0} %name"
                tm = re.search(r"%([\w.\-]+)\s*$", tok)
                if tm:
                    opnames.append(tm.group(1))
        out.append(Instr(name, type_str, opcode, opnames, attrs))
    return out


def _dot_flops(instr: Instr, symbols: Dict[str, str]) -> float:
    out_dims = _shape_dims(instr.type_str)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    contract = 1
    if m and instr.operands:
        lhs_type = symbols.get(instr.operands[0], "")
        lhs_dims = _shape_dims(lhs_type)
        for ax in m.group(1).split(","):
            if ax and int(ax) < len(lhs_dims):
                contract *= lhs_dims[int(ax)]
    return 2.0 * out_elems * contract


def _trip_count(cond_lines: List[str]) -> int:
    """Trip count from a scan-style loop condition.

    The condition computation's ROOT is ``compare(counter, bound)`` with
    direction LT; we resolve the bound through its constant definition.
    Taking the max constant anywhere in the condition is WRONG — shape-sized
    constants (e.g. a 32768 sequence bound) can appear in fused conditions.
    Falls back to the max constant only if the root isn't a simple compare.
    """
    instrs = _parse_instrs(cond_lines)
    consts = {}
    for ln in cond_lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\S+\s+constant\((\d+)\)",
                     ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "ROOT" not in ln:
            continue
        m = _INSTR_RE.match(ln)
        if m and m.group(3) == "compare":
            root = instrs[[i.name for i in instrs].index(m.group(1))] \
                if any(i.name == m.group(1) for i in instrs) else None
            if root:
                vals = [consts[o] for o in root.operands if o in consts]
                if vals:
                    return max(vals[0], 1)
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    parsed = {name: _parse_instrs(lines)
              for name, (lines, _) in comps.items()}
    entry = next((n for n, (_, is_e) in comps.items() if is_e), None)
    if entry is None:  # single-computation module
        entry = next(iter(comps)) if comps else None
    memo: Dict[str, HloStats] = {}

    def walk(comp_name: str) -> HloStats:
        if comp_name in memo:
            return memo[comp_name]
        stats = HloStats()
        symbols = {i.name: i.type_str for i in parsed.get(comp_name, [])}
        for instr in parsed.get(comp_name, []):
            if instr.opcode not in _PLUMBING_OPS:
                out_b = _shape_bytes(instr.type_str)
                if instr.opcode in ("dynamic-update-slice",):
                    # in-place update: traffic = read+write of the slice
                    upd = (_shape_bytes(symbols.get(instr.operands[1], ""))
                           if len(instr.operands) > 1 else 0)
                    bytes_moved = 2 * upd
                elif instr.opcode in ("dynamic-slice", "slice"):
                    bytes_moved = 2 * out_b  # sliced window r+w
                else:
                    in_b = sum(_shape_bytes(symbols.get(o, ""))
                               for o in instr.operands)
                    bytes_moved = out_b + in_b
                stats.hbm_bytes += bytes_moved
                if (instr.opcode in _MAJOR_OPS
                        or instr.opcode.startswith("fusion")):
                    stats.hbm_bytes_fused += bytes_moved
            if instr.opcode in ("dot",):
                stats.dot_flops += _dot_flops(instr, symbols)
            if instr.opcode.startswith("fusion"):
                # flops inside the fused computation still execute
                m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
                if m and m.group(1) in parsed:
                    sub = _flops_only(m.group(1))
                    stats.dot_flops += sub
            kind = _collective_kind(instr.opcode)
            if kind:
                stats.collective_bytes[kind] += in_b
                stats.collective_count[kind] += 1
            if instr.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", instr.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", instr.attrs)
                trips = _trip_count(comps.get(mc.group(1), ([], 0))[0]) \
                    if mc else 1
                if mb and mb.group(1) in parsed:
                    stats.add(walk(mb.group(1)), mult=trips)
            elif instr.opcode in ("call", "conditional", "async-start"):
                for m in re.finditer(
                        r"(?:to_apply|called_computations?|branch_computations)"
                        r"=\{?%?([\w.\-]+)", instr.attrs):
                    if m.group(1) in parsed:
                        stats.add(walk(m.group(1)))
        memo[comp_name] = stats
        return stats

    flops_memo: Dict[str, float] = {}

    def _flops_only(comp_name: str) -> float:
        if comp_name in flops_memo:
            return flops_memo[comp_name]
        total = 0.0
        symbols = {i.name: i.type_str for i in parsed.get(comp_name, [])}
        for instr in parsed.get(comp_name, []):
            if instr.opcode == "dot":
                total += _dot_flops(instr, symbols)
            elif instr.opcode.startswith("fusion"):
                m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
                if m and m.group(1) in parsed:
                    total += _flops_only(m.group(1))
        flops_memo[comp_name] = total
        return total

    if entry is None:
        return HloStats()
    return walk(entry)


def _collective_kind(opcode: str) -> Optional[str]:
    op = opcode.replace("-start", "")
    for k in COLLECTIVE_KINDS:
        if op == k or op == k + "-done":
            return k if not op.endswith("-done") else None
    return None


# ---------------------------------------------------------------------------
# Overlap analysis: did the compiler keep the start/done slack we created?
# ---------------------------------------------------------------------------
# Opcodes whose execution can hide an in-flight collective.  Fusions count:
# on every real backend the local matmul/accumulate of a schedule step
# compiles to a fusion (or a dot/convolution kept standalone).
_COMPUTE_OPS = frozenset({"dot", "convolution", "reduce"})


def _is_compute(opcode: str) -> bool:
    return opcode in _COMPUTE_OPS or opcode.startswith("fusion")


@dataclasses.dataclass
class OverlapReport:
    """How the compiled module treats its collectives (see analyze_overlap).

    ``overlapped``: async (``-start``/``-done``) collective pairs with at
    least one compute op scheduled strictly between start and done —
    transfers the runtime can fly under compute.  ``serialized``: async
    pairs whose done immediately follows the start (the slack the
    split-step bodies create was scheduled away).  ``sync``: collectives
    never split into start/done at all (always blocking).
    """
    overlapped: int = 0
    serialized: int = 0
    sync: int = 0
    pairs: List[Tuple[str, str, int]] = dataclasses.field(
        default_factory=list)   # (kind, start name, compute ops between)

    @property
    def async_total(self) -> int:
        return self.overlapped + self.serialized

    @property
    def eligible_fraction(self) -> float:
        """Fraction of async collectives with compute to hide under."""
        return self.overlapped / self.async_total if self.async_total else 0.0


def analyze_overlap(text: str) -> OverlapReport:
    """Classify every collective in an HLO module as overlap-eligible or not.

    Walks each computation in scheduled (textual) order.  A collective
    issued as an ``X-start`` whose matching ``X-done`` (or
    ``async-done`` consuming it) appears later with compute ops in
    between is *overlapped* — the program order gives the runtime room to
    run the transfer under that compute.  A start whose done is adjacent
    is *serialized*; a collective emitted in its fused blocking form is
    *sync*.  This is the verification half of the engine's split-step
    double-buffered bodies: after compiling with
    ``repro.runtime.platform`` overlap flags, the collective-permutes of
    a ring schedule's scan body should classify as overlapped.
    """
    report = OverlapReport()
    for name, (lines, _) in _split_computations(text).items():
        instrs = _parse_instrs(lines)
        for idx, instr in enumerate(instrs):
            op = instr.opcode
            kind = next((k for k in COLLECTIVE_KINDS
                         if op == k + "-start"), None)
            if kind is None and op == "async-start":
                # async-wrapped form: async-start(...), calls=<collective>
                m = re.search(r"calls=%?([\w.\-]+)", instr.attrs)
                kind = "async"
                if m:
                    for k in COLLECTIVE_KINDS:
                        if k in m.group(1):
                            kind = k
                            break
            if kind is not None:
                # find the matching done: the later instruction consuming
                # this start's value
                compute = 0
                done_idx = None
                for j in range(idx + 1, len(instrs)):
                    if instr.name in instrs[j].operands and (
                            instrs[j].opcode.endswith("-done")):
                        done_idx = j
                        break
                    if _is_compute(instrs[j].opcode):
                        compute += 1
                if done_idx is None:
                    continue    # malformed/truncated module
                if compute:
                    report.overlapped += 1
                else:
                    report.serialized += 1
                report.pairs.append((kind, instr.name, compute))
            elif op in COLLECTIVE_KINDS:
                report.sync += 1
    return report


_OP_NAME_RE = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')


def scope_op_counts(text: str, scope: Optional[str] = None
                    ) -> Dict[str, int]:
    """Count HLO instructions per ``jax.named_scope`` label.

    ``MatmulPlan`` wraps every schedule body in
    ``jax.named_scope("plan.<algorithm>.<wire>")`` and the serving
    segments in ``serve.*`` scopes; the labels survive into the compiled
    module's ``metadata={op_name=...}`` strings, so an XLA profile — or
    this compile-time proxy — attributes device ops to schedule steps by
    name.  Returns ``{scope_component: n_instructions}`` over every
    scope component seen (path components of each op_name, deduplicated
    per instruction); with ``scope=`` given, only components containing
    that substring are counted.
    """
    counts: Dict[str, int] = {}
    for m in _OP_NAME_RE.finditer(text):
        seen = set()
        for comp in m.group(1).split("/"):
            comp = comp.strip()
            if not comp or comp in seen:
                continue
            seen.add(comp)
            if scope is not None and scope not in comp:
                continue
            counts[comp] = counts.get(comp, 0) + 1
    return counts
