"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1

Wires together: config registry, synthetic/memmap data pipeline (prefetch),
AdamW, GSPMD sharding over an (optionally multi-pod) mesh, checkpoint/
restart, straggler detection, and preemption handling.  On this CPU
container it trains reduced configs; the same driver lowers the full configs
on a TPU cluster.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import numpy as np


def build_mesh(n_devices: Optional[int] = None):
    import jax
    from repro.compat import make_mesh
    from repro.runtime.elastic import choose_mesh_shape

    n = n_devices or len(jax.devices())
    data, model = choose_mesh_shape(n, max_model=16)
    return make_mesh((data, model), ("data", "model"))


def train(cfg, *, steps: int, batch: int, seq: int, lr: float = 3e-4,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          seed: int = 0, mesh=None, log_every: int = 10,
          resume: bool = True, max_restarts: int = 3,
          stop_after: Optional[int] = None):
    """``stop_after`` stops early (crash/preemption emulation) while keeping
    the LR schedule pinned to the job's total ``steps`` — a restarted job
    must see the same schedule."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ckpt import CheckpointManager
    from repro.data.pipeline import Prefetcher, SyntheticLM
    from repro.launch.mesh import batch_partition_spec
    from repro.models import lm, transformer as tf
    from repro.optim import AdamW, cosine_schedule
    from repro.runtime import PreemptionSignal, RestartableLoop, StragglerDetector

    from repro.compat import set_mesh
    from repro.launch.mesh import sanitized_shardings

    mesh = mesh or build_mesh()
    opt = AdamW(lr=cosine_schedule(lr, max(steps // 20, 1), steps))
    pspecs = tf.param_specs(cfg)
    abstract = jax.eval_shape(lambda k: tf.init_params(cfg, k),
                              jax.random.PRNGKey(seed))
    param_sh = sanitized_shardings(pspecs, abstract, mesh)
    opt_sh = sanitized_shardings(
        AdamW.state_specs(pspecs),
        jax.eval_shape(opt.init, abstract), mesh)

    with set_mesh(mesh):
        params = jax.jit(
            lambda k: tf.init_params(cfg, k),
            out_shardings=param_sh)(jax.random.PRNGKey(seed))
        opt_state = jax.jit(opt.init, out_shardings=opt_sh)(params)
        step_fn = jax.jit(lm.make_train_step(cfg, opt),
                          donate_argnums=(0, 1))

        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        start_step = 0
        if mgr and resume and mgr.latest_step() is not None:
            start_step, (params, opt_state), _ = mgr.restore(
                None, (params, opt_state), (param_sh, opt_sh))
            print(f"[train] resumed from step {start_step}")

        source = SyntheticLM(cfg, batch, seq, seed=seed)
        prefetch = Prefetcher(source, depth=2, start_step=start_step)
        straggler = StragglerDetector()
        preempt = PreemptionSignal(install=False)
        bspec = batch_partition_spec(batch, mesh)
        state = {"params": params, "opt": opt_state, "losses": []}

        def recover() -> int:
            if not mgr:
                return 0
            s, (p, o), _ = mgr.restore(None, (state["params"], state["opt"]),
                                       (param_sh, opt_sh))
            state["params"], state["opt"] = p, o
            return s

        def body(step: int):
            t0 = time.time()
            raw = prefetch.get(step)
            dev_batch = {
                k: jax.device_put(v, NamedSharding(
                    mesh, P(bspec[0], *([None] * (v.ndim - 1)))))
                for k, v in raw.items()}
            state["params"], state["opt"], metrics = step_fn(
                state["params"], state["opt"], dev_batch)
            loss = float(metrics["loss"])
            state["losses"].append(loss)
            dt = time.time() - t0
            if straggler.observe(step, dt):
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"(mean {straggler.mean:.2f}s)")
            if log_every and step % log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt * 1e3:.0f}ms")
            if mgr and step and step % ckpt_every == 0:
                mgr.save(step, state["params"], state["opt"],
                         extra={"loss": loss})
            if preempt.requested:
                if mgr:
                    mgr.save(step, state["params"], state["opt"])
                    mgr.wait()
                raise SystemExit(0)

        total = min(stop_after, steps) if stop_after else steps
        loop = RestartableLoop(total, recover, max_restarts=max_restarts,
                               on_restart=lambda s, e: print(
                                   f"[restart] step {s}: {e}"))
        end = start_step
        try:
            end = loop.run(body, start_step)
        finally:
            prefetch.close()
            if mgr:
                mgr.save(end, state["params"], state["opt"])
                mgr.wait()
    return state


# ---------------------------------------------------------------------------
# DP/TP equivalence selftest (used by launch/selftest.py)
# ---------------------------------------------------------------------------
def selftest_parallel_equivalence(n_devices: int) -> bool:
    """loss(sharded over (data, model)) == loss(single-device), same batch."""
    import jax
    import jax.numpy as jnp

    from repro.compat import make_mesh, set_mesh
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import shardings_for
    from repro.models import lm, transformer as tf

    cfg = get_config("llama3-8b", smoke=True)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in SyntheticLM(cfg, 4, 16, seed=1)(0).items()}
    loss_ref, _ = lm.loss_fn(params, batch, cfg)

    data = max(1, n_devices // 2)
    mesh = make_mesh((data, n_devices // data), ("data", "model"))
    with set_mesh(mesh):
        param_sh = shardings_for(tf.param_specs(cfg), mesh)
        p_sh = jax.device_put(params, param_sh)
        loss_sh, _ = jax.jit(
            lambda p, b: lm.loss_fn(p, b, cfg))(p_sh, batch)
    return abs(float(loss_ref) - float(loss_sh)) < 1e-3


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.configs import get_config
    cfg = get_config(args.arch, smoke=args.smoke)
    state = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                  lr=args.lr, ckpt_dir=args.ckpt_dir,
                  ckpt_every=args.ckpt_every, seed=args.seed)
    losses = state["losses"]
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"[train] first-{k} mean loss {np.mean(losses[:k]):.4f} -> "
              f"last-{k} mean loss {np.mean(losses[-k:]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
