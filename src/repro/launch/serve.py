"""Serving CLI — a thin wrapper over ``repro.serving.ServeEngine``.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 4 --prompt-len 16 --gen-len 16 [--sparse]

The engine does the real work: bucketed admission, continuous batching,
per-window timing (prefill and decode are measured separately, each
blocking on its outputs — the old loop here timed prefill without a
``block_until_ready``, letting async dispatch smear prefill work into the
decode window), and MoE dropped-token stats threaded into the metrics
layer.  ``--sparse`` routes MoE dispatch and prefill attention scoring
through the ``DistBSR``/``plan_matmul`` engine.
"""
from __future__ import annotations

import argparse

import numpy as np


def serve(cfg, *, requests: int, prompt_len: int, gen_len: int,
          max_len: int = None, seed: int = 0, mesh=None,
          sparse: bool = False, max_batch: int = None):
    """Serve ``requests`` synthetic prompts; returns generations + metrics."""
    from repro.serving import ServeEngine

    max_len = max_len or (prompt_len + gen_len + 8)
    rng = np.random.default_rng(seed)
    engine = ServeEngine(cfg, seed=seed, max_len=max_len, mesh=mesh,
                         sparse=sparse,
                         max_batch=max_batch or min(requests, 4))
    for _ in range(requests):
        engine.submit(rng.integers(0, cfg.vocab_size, (prompt_len,)),
                      max_new_tokens=gen_len)
    results = engine.run()
    stats = engine.summary()
    gen = np.stack([results[rid] for rid in sorted(results)])
    return {
        "generated": gen,
        "prefill_s": stats["prefill_s"],
        "decode_s": stats["decode_s"],
        "decode_tok_per_s": stats["decode_tok_per_s"] or 0.0,
        "metrics": stats,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen-len", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--sparse", action="store_true",
                   help="route MoE dispatch / attention scoring through "
                        "the DistBSR plan engine")
    p.add_argument("--trace", metavar="PATH", default=None,
                   help="record an execution trace of the serve run and "
                        "write Chrome-trace JSON to PATH (open in "
                        "ui.perfetto.dev; summarize with "
                        "tools/trace_view.py)")
    args = p.parse_args(argv)

    from repro import obs
    from repro.configs import get_config
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only; no serve path")
    if args.trace:
        obs.enable(clear=True)
    out = serve(cfg, requests=args.requests, prompt_len=args.prompt_len,
                gen_len=args.gen_len, seed=args.seed, sparse=args.sparse)
    if args.trace:
        obs.disable()
        trace = obs.export_trace(args.trace)
        print(f"[serve] wrote {len(trace['traceEvents'])} trace events "
              f"to {args.trace}")
        drift = obs.drift_report()
        for key, d in sorted(drift.items()):
            print(f"[serve] drift {key}: ratio {d['ratio']:.2f} "
                  f"over {d['n']} multiplies")
    m = out["metrics"]
    print(f"[serve] prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_s']:.2f}s "
          f"({out['decode_tok_per_s']:.1f} tok/s)")
    print(f"[serve] ttft p50/p99 {m['ttft_p50_s']:.3f}/{m['ttft_p99_s']:.3f}s"
          f", tpot p50/p99 {m['tpot_p50_s']:.3f}/{m['tpot_p99_s']:.3f}s")
    print(f"[serve] plan lookups {m['plan_lookups']} "
          f"(hit rate {m['plan_cache_hit_rate']}), "
          f"dropped mean/max {m['dropped_mean']:.4f}/{m['dropped_max']:.4f}")
    print(f"[serve] sample generation: {out['generated'][0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
