"""Batched serving driver: continuous prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 4 --prompt-len 16 --gen-len 16

Demonstrates the serving path end-to-end: batched prefill, KV/state cache
management (ring buffers for local attention; SSM/RG-LRU states), stepwise
decode, simple request batching with padding.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def serve(cfg, *, requests: int, prompt_len: int, gen_len: int,
          max_len: int = None, seed: int = 0, mesh=None):
    import jax
    import jax.numpy as jnp

    from repro.models import lm, transformer as tf

    max_len = max_len or (prompt_len + gen_len + 8)
    params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (requests, prompt_len))
    batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.frontend == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal(
                (requests, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)

    t0 = time.time()
    logits, caches, pos = lm.prefill(params, batch, cfg, max_len,
                                     cache_dtype=jnp.float32)
    t_prefill = time.time() - t0
    step = jax.jit(lm.make_decode_step(cfg))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen_len - 1):
        logits, caches = step(params, tok, caches, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos = pos + 1
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": requests * (gen_len - 1) / max(t_decode, 1e-9),
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen-len", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.configs import get_config
    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only; no serve path")
    out = serve(cfg, requests=args.requests, prompt_len=args.prompt_len,
                gen_len=args.gen_len, seed=args.seed)
    print(f"[serve] prefill {out['prefill_s']:.2f}s, "
          f"decode {out['decode_s']:.2f}s "
          f"({out['decode_tok_per_s']:.1f} tok/s)")
    print(f"[serve] sample generation: {out['generated'][0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
