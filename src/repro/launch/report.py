"""Render EXPERIMENTS.md tables from the dry-run JSON cache.

  PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_GB = 16.0  # v5e HBM per chip


def load(dir_: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | args GiB/chip | "
        "temp GiB/chip | HLO flops/chip | collective counts |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | - | - "
                f"| - | - | {r['reason'][:60]} |")
            continue
        mem = r.get("memory_analysis", {})
        cc = r.get("hlo_stats", {}).get("collective_count", {})
        cc_s = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in cc.items()
                        if v) or "none"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['compile_seconds']} "
            f"| {fmt_bytes(mem.get('argument_size_in_bytes'))} "
            f"| {fmt_bytes(mem.get('temp_size_in_bytes'))} "
            f"| {r['hlo_stats']['dot_flops']:.3e} | {cc_s} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| model_flops | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        # roofline fraction: ideal-compute time / dominant achieved term
        ideal = rf["model_flops"] / r["n_chips"] / PEAK_FLOPS
        frac = ideal / dom if dom else 0.0
        ur = rf.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} "
            f"| {rf['memory_s']:.3e} | {rf['collective_s']:.3e} "
            f"| {rf['bottleneck'].replace('_s', '')} "
            f"| {rf['model_flops']:.3e} "
            f"| {ur if ur is None else round(ur, 3)} "
            f"| {frac:.4f} |")
    return "\n".join(lines)


def worst_cells(recs: List[Dict], mesh: str = "single", n: int = 5):
    rows = []
    for r in recs:
        if r["status"] != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        ideal = rf["model_flops"] / r["n_chips"] / PEAK_FLOPS
        rows.append((ideal / dom if dom else 0.0, r["arch"], r["shape"],
                     rf["bottleneck"]))
    rows.sort()
    return rows[:n]


def reanalyze(dir_: str) -> None:
    """Recompute hlo_stats/roofline in every JSON from the cached .hlo.gz
    (after analyzer changes) without recompiling."""
    import gzip

    from repro.launch import hlo_analysis
    from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS as PEAK

    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        hlo_path = path.replace(".json", "") + ".hlo.gz"
        if rec.get("status") != "ok" or not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            stats = hlo_analysis.analyze_hlo(f.read())
        terms = {"compute_s": stats.dot_flops / PEAK,
                 "memory_s": stats.hbm_bytes_fused / HBM_BW,
                 "collective_s": stats.total_collective_bytes / ICI_BW}
        mf = rec["roofline"]["model_flops"]
        rec["hlo_stats"] = {
            "dot_flops": stats.dot_flops,
            "hbm_bytes": stats.hbm_bytes,
            "hbm_bytes_fused": stats.hbm_bytes_fused,
            "collective_bytes": stats.collective_bytes,
            "collective_count": stats.collective_count,
        }
        rec["roofline"] = {
            **terms, "bottleneck": max(terms, key=terms.get),
            "model_flops": mf,
            "useful_flops_ratio": (mf / (stats.dot_flops * rec["n_chips"])
                                   if stats.dot_flops else None),
        }
        with open(path, "w") as f:
            json.dump(rec, f, indent=2, default=float)
        print(f"reanalyzed {os.path.basename(path)}")


def compare(baseline_path: str, dir_: str, mesh: str = "single") -> str:
    """Markdown diff of dominant roofline terms: baseline report vs now."""
    base = {}
    for path in sorted(glob.glob(os.path.join("results",
                                              "baseline_*__%s.json" % mesh))):
        with open(path) as f:
            r = json.load(f)
        base[(r["arch"], r["shape"])] = r
    lines = ["| cell | baseline dominant | optimized dominant | speedup |",
             "|---|---|---|---|"]
    for (arch, shape), rb in sorted(base.items()):
        cur_path = os.path.join(dir_, f"{arch}__{shape}__{mesh}.json")
        if not os.path.exists(cur_path):
            continue
        with open(cur_path) as f:
            rc = json.load(f)
        tb = rb["roofline"]
        tc = rc["roofline"]
        db = max(tb["compute_s"], tb["memory_s"], tb["collective_s"])
        dc = max(tc["compute_s"], tc["memory_s"], tc["collective_s"])
        lines.append(
            f"| {arch} {shape} | {db:.3e} ({tb['bottleneck'][:-2]}) "
            f"| {dc:.3e} ({tc['bottleneck'][:-2]}) | {db / dc:.1f}x |")
    return "\n".join(lines)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="results/dryrun")
    p.add_argument("--mesh", default="single")
    p.add_argument("--reanalyze", action="store_true")
    p.add_argument("--compare", action="store_true")
    args = p.parse_args()
    if args.compare:
        print(compare("results", args.dir, args.mesh))
        return
    if args.reanalyze:
        reanalyze(args.dir)
        return
    recs = load(args.dir)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16 = 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n### Worst roofline fractions (hillclimb candidates)\n")
    for frac, arch, shape, bn in worst_cells(recs):
        print(f"- {arch} {shape}: {frac:.4f} ({bn})")


if __name__ == "__main__":
    main()
